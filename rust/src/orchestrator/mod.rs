//! Shard orchestrator: one command turns a sweep grid into a
//! supervised fleet of `memfine sweep --shard i/n` child processes
//! and a single merged, verified, compacted golden artifact.
//!
//! PR 2 made sharded execution *possible* (`--shard i/n`,
//! content-hash checkpoints, byte-identical merge) but left the
//! operator to spawn each shard, babysit crashes, and merge by hand.
//! This module is the scheduler layer that owns placement and
//! recovery instead (the MicroMoE/MoEBlaze lesson: the scale win
//! lives in the supervisor, not the worker):
//!
//! * [`plan`] — split the grid round-robin over trace cells into
//!   `--procs N` shard plans (reusing
//!   [`ShardSpec`](crate::config::ShardSpec) semantics, so no shard
//!   re-draws another's routing traces) and derive the full planned
//!   scenario-hash set — the launch's coverage contract.
//! * [`supervise`] — spawn one child per shard via `std::process`,
//!   infer liveness from checkpoint-file growth ([`health`]), kill
//!   and relaunch crashed or stalled children with `--resume` under a
//!   bounded retry budget, and summarise each shard's fate.
//! * [`merge`] — fold every shard checkpoint through the sweep
//!   engine's resume path (which doubles as the final catch-up shard
//!   for any gap), audit coverage against the plan, and compact the
//!   merged checkpoint (dedupe by hash, drop torn tails, rewrite
//!   canonically) so long campaigns stay bounded.
//!
//! The determinism contract extends end to end: however many
//! processes run the grid, however often they crash, stall, or get
//! chaos-killed, the published artifact is byte-identical to a
//! single-process `memfine sweep` of the same `SweepConfig` —
//! `tests/integration_launch.rs` pins exactly that, kills included.

pub mod chaos;
pub mod health;
pub mod host;
pub mod merge;
pub mod plan;
pub mod supervise;

pub use chaos::FaultPlan;
pub use health::{probe_len, probe_mtime_age, HeartbeatMonitor};
pub use host::{lease_path, HostKind, HostPool, HostSlot, HostSpec, LeaseMonitor};
pub use merge::{merge_and_finish, MergeOutcome};
pub use plan::{plan_shards, LaunchPlan, ShardPlan};
pub use supervise::{
    supervise, supervise_fleet, RetryPolicy, ShardEvent, ShardEventKind,
    ShardOutcome, SuperviseOptions, QUARANTINE_SUFFIX,
};

use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use crate::config::LaunchConfig;
use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::obs::{EventLog, WatchConfig, Watchdog};
use crate::util;

/// Execution parameters of one launch invocation — everything that
/// decides *where and how* the fleet runs but can never reach the
/// artifact bytes (the [`LaunchConfig`] <-> `LaunchOptions` split
/// mirrors `SweepConfig` <-> `SweepRunOptions`).
#[derive(Clone, Debug)]
pub struct LaunchOptions {
    /// Working directory for the launch: shard checkpoints and logs,
    /// the captured `sweep.json`/`launch.json` specs, and the final
    /// `merged.jsonl` live here. Created if missing.
    pub dir: PathBuf,
    /// The `memfine` binary to spawn shards with; defaults to the
    /// current executable (correct for `memfine launch`; tests and
    /// benches pass `CARGO_BIN_EXE_memfine`).
    pub binary: Option<PathBuf>,
    /// Run a chaos drill against the fleet: scripted kills, checkpoint
    /// corruption, slow shards, whole-host losses, and injected IO
    /// faults (see [`chaos::FaultPlan`]). `FaultPlan::kill_one()`
    /// reproduces the legacy `--chaos-kill` drill.
    pub fault_plan: Option<chaos::FaultPlan>,
    /// Global trace-cache root shared *across campaigns* (and hosts on
    /// shared storage): children and the merge catch-up stack it
    /// behind the per-campaign tier, so a cell's routed stream is
    /// drawn at most once per fleet, not once per campaign.
    /// Execution-only — cache placement can never reach the artifact
    /// bytes.
    pub trace_cache_global: Option<PathBuf>,
    /// Suppress the per-event log lines (library/bench use).
    pub quiet: bool,
}

impl LaunchOptions {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        LaunchOptions {
            dir: dir.into(),
            binary: None,
            fault_plan: None,
            trace_cache_global: None,
            quiet: false,
        }
    }
}

/// Everything a finished launch produced, for the CLI to summarise
/// and tests to dissect.
#[derive(Debug)]
pub struct LaunchReport {
    pub plan: LaunchPlan,
    pub outcomes: Vec<ShardOutcome>,
    /// Every supervision event, in emission order.
    pub events: Vec<ShardEvent>,
    pub merge: MergeOutcome,
}

fn describe(ev: &ShardEvent) -> String {
    let s = ev.shard;
    match &ev.kind {
        ShardEventKind::Spawned { pid, attempt } => {
            format!("shard {s}: spawned pid {pid} (attempt {attempt})")
        }
        ShardEventKind::Progress { checkpoint_bytes } => {
            format!("shard {s}: checkpoint at {checkpoint_bytes} B")
        }
        ShardEventKind::ChaosKilled { pid } => {
            format!("shard {s}: CHAOS killed pid {pid}")
        }
        ShardEventKind::Stalled { idle_ms } => {
            format!("shard {s}: stalled {idle_ms} ms, killing")
        }
        ShardEventKind::Crashed { exit_code } => match exit_code {
            Some(c) => format!("shard {s}: exited with code {c}"),
            None => format!("shard {s}: killed by signal"),
        },
        ShardEventKind::Backoff { delay_ms } => {
            format!("shard {s}: backing off {delay_ms} ms before relaunch")
        }
        ShardEventKind::Completed => format!("shard {s}: completed"),
        ShardEventKind::GaveUp { reason } => {
            format!("shard {s}: giving up ({reason})")
        }
        ShardEventKind::Quarantined { reason } => {
            format!("shard {s}: checkpoint quarantined ({reason})")
        }
        ShardEventKind::ChaosCorrupted { mode, bytes } => {
            format!("shard {s}: CHAOS corrupted checkpoint ({mode}, {bytes} B)")
        }
        ShardEventKind::HostLost { host } => {
            format!("host {host}: lease expired, declaring the host LOST")
        }
        ShardEventKind::Reassigned { from_host, to_host } => {
            format!("shard {s}: reassigned {from_host} -> {to_host}")
        }
    }
}

/// The campaign event log's view of one supervision event: shard
/// index plus the kind-specific payload, under stable field names so
/// `memfine events` filters stay meaningful across versions.
fn shard_event_fields(ev: &ShardEvent) -> Vec<(&'static str, Value)> {
    let mut fields = vec![("shard", json::num(ev.shard as f64))];
    match &ev.kind {
        ShardEventKind::Spawned { pid, attempt } => {
            fields.push(("child_pid", json::num(*pid as f64)));
            fields.push(("attempt", json::num(*attempt as f64)));
        }
        ShardEventKind::Progress { checkpoint_bytes } => {
            fields.push(("checkpoint_bytes", json::num(*checkpoint_bytes as f64)));
        }
        ShardEventKind::ChaosKilled { pid } => {
            fields.push(("child_pid", json::num(*pid as f64)));
        }
        ShardEventKind::Stalled { idle_ms } => {
            fields.push(("idle_ms", json::num(*idle_ms as f64)));
        }
        ShardEventKind::Crashed { exit_code } => {
            fields.push((
                "exit_code",
                match exit_code {
                    Some(c) => json::num(*c as f64),
                    None => Value::Null,
                },
            ));
        }
        ShardEventKind::Backoff { delay_ms } => {
            fields.push(("delay_ms", json::num(*delay_ms as f64)));
        }
        ShardEventKind::Completed => {}
        ShardEventKind::GaveUp { reason } => {
            fields.push(("reason", json::s(reason.clone())));
        }
        ShardEventKind::Quarantined { reason } => {
            fields.push(("reason", json::s(reason.clone())));
        }
        ShardEventKind::ChaosCorrupted { mode, bytes } => {
            fields.push(("mode", json::s(mode.clone())));
            fields.push(("bytes", json::num(*bytes as f64)));
        }
        ShardEventKind::HostLost { host } => {
            fields.push(("host", json::s(host.clone())));
        }
        ShardEventKind::Reassigned { from_host, to_host } => {
            fields.push(("from_host", json::s(from_host.clone())));
            fields.push(("to_host", json::s(to_host.clone())));
        }
    }
    fields
}

/// Run a full orchestrated launch: plan the fleet, capture the specs
/// into the launch dir, spawn and supervise the shard processes, then
/// merge / heal / audit / compact into the final report. A shard that
/// exhausts its retry budget does not fail the launch as long as the
/// in-process catch-up can execute its scenarios — supervision is an
/// optimisation, the artifact contract is absolute.
pub fn launch(cfg: &LaunchConfig, opts: &LaunchOptions) -> Result<LaunchReport> {
    cfg.validate()?;
    std::fs::create_dir_all(&opts.dir)?;
    let plan = plan::plan_shards(cfg, &opts.dir)?;

    // A launch dir is one campaign. Re-entering it with the same grid
    // (and sampler) is a legitimate resume — children pick up their
    // shard checkpoints; re-entering with a *different* campaign is
    // refused: children would fold nothing from the stale files, but
    // the compacted merged.jsonl would accrete the old campaign's
    // records and grow without bound.
    // Checkpoint lists travel to children as comma-separated
    // `--checkpoint` values, so the dir path itself must be
    // comma-free — refuse loudly instead of spawning shards that
    // split their own paths apart.
    if opts.dir.display().to_string().contains(',') {
        return Err(Error::config(format!(
            "launch dir {} contains ',' — checkpoint lists are \
             comma-separated, pick another --dir",
            opts.dir.display()
        )));
    }
    let launch_json = opts.dir.join("launch.json");
    // events.jsonl is the sidecar telemetry log, never checkpoint
    // state: it must not block a fresh campaign nor be folded into
    // merged.jsonl.
    let is_event_log = |p: &std::path::Path| {
        p.file_name().and_then(|n| n.to_str()) == Some("events.jsonl")
    };
    let dir_has_jsonl = || -> Result<bool> {
        Ok(std::fs::read_dir(&opts.dir)?.filter_map(|e| e.ok()).any(|e| {
            let p = e.path();
            p.extension().and_then(|x| x.to_str()) == Some("jsonl")
                && !is_event_log(&p)
        }))
    };
    match std::fs::read_to_string(&launch_json) {
        Ok(prev_text) => {
            let same_campaign = crate::json::parse(&prev_text)
                .ok()
                .and_then(|v| LaunchConfig::from_json(&v).ok())
                .is_some_and(|prev| {
                    prev.sweep == cfg.sweep
                        && prev.sampler == cfg.sampler
                        && prev.rng == cfg.rng
                });
            if !same_campaign {
                return Err(Error::config(format!(
                    "launch dir {} already holds a different campaign \
                     (launch.json does not match this grid); use a fresh \
                     --dir or remove the old one",
                    opts.dir.display()
                )));
            }
        }
        // No campaign record: only a dir without prior checkpoint
        // state may start one — stray .jsonl files of unknown
        // provenance would otherwise be absorbed into merged.jsonl.
        Err(_) => {
            if dir_has_jsonl()? {
                return Err(Error::config(format!(
                    "launch dir {} holds .jsonl checkpoints but no \
                     launch.json to prove they belong to this campaign; \
                     use a fresh --dir or remove them",
                    opts.dir.display()
                )));
            }
        }
    }

    // Capture the campaign next to its artifacts: children load the
    // grid from sweep.json (no lossy CLI round-trip), and launch.json
    // documents the whole launch for audits and re-runs.
    let sweep_json = opts.dir.join("sweep.json");
    std::fs::write(
        &sweep_json,
        format!("{}\n", cfg.sweep.to_json().to_string_pretty()),
    )?;
    std::fs::write(
        &launch_json,
        format!("{}\n", cfg.to_json().to_string_pretty()),
    )?;

    let binary = match &opts.binary {
        Some(b) => b.clone(),
        None => std::env::current_exe().map_err(Error::Io)?,
    };

    // Every .jsonl already in the campaign dir is prior same-campaign
    // state (the guard above enforces one campaign per dir): earlier
    // shard files, or the merged.jsonl of a finished run. Children
    // read them all on resume, so an interrupted campaign relaunched
    // with a different process count (new shard file names) still
    // reuses every completed scenario instead of re-executing it.
    let mut prior_state: Vec<PathBuf> = std::fs::read_dir(&opts.dir)?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| {
            p.extension().and_then(|e| e.to_str()) == Some("jsonl")
                && !is_event_log(p)
        })
        .collect();
    prior_state.sort();

    // Multi-host mode: parse the host list up front (a bad spec must
    // fail before any child spawns), and refuse comma-bearing global
    // cache paths for the same reason the dir must be comma-free —
    // they travel to children inside a comma-separated flag value.
    let host_specs = host::HostSpec::parse_list(&cfg.hosts)?;
    let multi_host = !host_specs.is_empty();
    if let Some(g) = &opts.trace_cache_global {
        if g.display().to_string().contains(',') {
            return Err(Error::config(format!(
                "global trace cache {} contains ',' — the child flag is \
                 comma-separated, pick another --trace-cache",
                g.display()
            )));
        }
    }

    let workers = cfg.workers_per_proc;
    let sampler = cfg.sampler;
    let rng = cfg.rng;
    let pin_cores = cfg.pin_cores;
    // One campaign event log, shared by appending: the supervisor and
    // every shard child write whole lines O_APPEND to the same file.
    // Strictly sidecar — open failure degrades to a disabled log.
    let events_path = opts.dir.join("events.jsonl");
    let elog = if cfg.telemetry {
        EventLog::open(&events_path)
    } else {
        EventLog::disabled()
    };
    elog.emit(
        "launch_start",
        vec![
            ("procs", json::num(plan.procs as f64)),
            ("shards", json::num(plan.shards.len() as f64)),
            ("cells", json::num(plan.total_cells as f64)),
            ("scenarios", json::num(plan.total_scenarios as f64)),
            ("chaos", Value::Bool(opts.fault_plan.is_some())),
        ],
    );
    // Scripted IO faults: supervisor-scope specs arm this process's
    // fault seam directly; children-scope specs travel by env var and
    // only to each shard's FIRST attempt, so relaunches (and the
    // in-process merge catch-up) always run clean and the campaign
    // still converges.
    if let Some(p) = &opts.fault_plan {
        p.arm_supervisor_faults();
    }
    let child_fault_env = opts.fault_plan.as_ref().and_then(|p| p.child_fault_env());
    // One trace cache per campaign dir: every shard process (and the
    // merge catch-up) shares it, so a cell's routed stream is drawn at
    // most once per campaign — and relaunches/topology changes reuse
    // it across runs.
    let trace_cache = opts.dir.join("trace-cache");
    let prior = &prior_state;
    let events_enabled = elog.enabled();
    // The one command builder every host shares; only *where* it runs
    // differs (a local Command vs. an ssh wrap of the same argv).
    let spawn_cmd = |kind: &host::HostKind,
                     shard: &ShardPlan,
                     attempt: u32|
     -> Result<std::process::Child> {
        let log = std::fs::File::options()
            .create(true)
            .append(true)
            .open(&shard.log)
            .map_err(Error::Io)?;
        // own checkpoint first (the write target), prior state after
        // (read-only resume sources)
        let mut checkpoints = shard.checkpoint.display().to_string();
        for src in prior.iter().filter(|p| **p != shard.checkpoint) {
            checkpoints.push(',');
            checkpoints.push_str(&src.display().to_string());
        }
        // per-campaign tier, with the cross-campaign global root
        // stacked behind it when configured
        let cache_arg = match &opts.trace_cache_global {
            Some(g) => format!("{},{}", trace_cache.display(), g.display()),
            None => trace_cache.display().to_string(),
        };
        let mut argv: Vec<String> = vec![
            "sweep".into(),
            "--config".into(),
            sweep_json.display().to_string(),
            "--shard".into(),
            format!("{}/{}", shard.spec.index, shard.spec.count),
            "--checkpoint".into(),
            checkpoints,
            // always resume: relaunches continue from the checkpoint,
            // first launches find nothing and start clean
            "--resume".into(),
            "--workers".into(),
            workers.to_string(),
            // explicit sampler and generator: children must not depend
            // on defaults matching across binary versions
            "--router".into(),
            sampler.tag().to_string(),
            "--rng".into(),
            rng.tag().to_string(),
            "--trace-cache".into(),
            cache_arg,
            "--out".into(),
            "-".into(),
        ];
        if pin_cores {
            // execution-only: pinned and unpinned shards produce the
            // same checkpoint bytes, this just steadies throughput
            argv.push("--pin-cores".into());
        }
        if events_enabled {
            // children append their engine events (cell_eval, cache
            // hit/miss, checkpoint appends) to the same campaign log
            argv.push("--events".into());
            argv.push(events_path.display().to_string());
        }
        let fault = if attempt == 1 { child_fault_env.as_deref() } else { None };
        let mut cmd = match kind {
            host::HostKind::Local => {
                let mut cmd = Command::new(&binary);
                cmd.args(&argv);
                if let Some(env) = fault {
                    cmd.env(crate::faultfs::FAULT_ENV, env);
                }
                cmd
            }
            host::HostKind::Ssh { target } => {
                let mut full = vec![binary.display().to_string()];
                full.extend(argv.iter().cloned());
                host::ssh_command(
                    target,
                    &full,
                    fault.map(|v| (crate::faultfs::FAULT_ENV, v)),
                )
            }
        };
        cmd.stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::from(log));
        cmd.spawn().map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("spawn shard {}: {e}", shard.index),
            ))
        })
    };
    let spawn_ref = &spawn_cmd;
    let slots: Vec<host::HostSlot<'_>> = if multi_host {
        host_specs
            .iter()
            .map(|spec| {
                let kind = spec.kind.clone();
                host::HostSlot::new(
                    spec.clone(),
                    Box::new(move |shard: &ShardPlan, attempt: u32| {
                        spawn_ref(&kind, shard, attempt)
                    }),
                )
            })
            .collect()
    } else {
        vec![host::HostSlot::new(
            host::HostSpec { id: "h0".into(), kind: host::HostKind::Local },
            Box::new(move |shard: &ShardPlan, attempt: u32| {
                spawn_ref(&host::HostKind::Local, shard, attempt)
            }),
        )]
    };
    let mut pool = host::HostPool::new(slots)?;
    if multi_host {
        // the lease plane lives in the campaign dir: every host's
        // `.lease` file sits next to the checkpoints it vouches for
        pool.with_leases(
            &opts.dir,
            Duration::from_millis(cfg.lease_timeout_ms),
            Instant::now(),
        )?;
    }

    let sup_opts = SuperviseOptions {
        stall_timeout: Duration::from_millis(cfg.stall_timeout_ms),
        poll_interval: Duration::from_millis(cfg.poll_ms),
        policy: RetryPolicy {
            episode_retries: cfg.max_retries.min(u32::MAX as u64) as u32,
            campaign_retries: cfg.campaign_retries.min(u32::MAX as u64) as u32,
            backoff_base: Duration::from_millis(cfg.backoff_ms),
            backoff_cap: Duration::from_secs(10),
            // keyed on the campaign dir so a replayed drill backs off
            // identically, but two campaigns don't sync their retries
            jitter_seed: util::fnv1a_64(opts.dir.display().to_string().as_bytes()),
            quarantine: cfg.quarantine,
        },
        fault_plan: opts.fault_plan.clone(),
    };
    let quiet = opts.quiet;
    // The watchdog tails the same events.jsonl everyone appends to and
    // raises each alert_* kind at most once; alerts land back in the
    // event log so `memfine status` and chaos drills can assert on
    // them.
    let mut watchdog = Watchdog::new(WatchConfig::default());
    let mut events: Vec<ShardEvent> = Vec::new();
    let watch_enabled = elog.enabled();
    // Host-tagged telemetry: in multi-host mode every shard event
    // carries the shard's current host id. The map is rebuilt from the
    // event stream itself (initial round-robin + Reassigned updates),
    // which is exactly how `memfine status` reconstructs it later.
    let host_names: Option<Vec<String>> = if multi_host {
        Some(host_specs.iter().map(|h| h.id.clone()).collect())
    } else {
        None
    };
    let mut host_of: Vec<usize> = (0..plan.shards.len())
        .map(|i| i % host_specs.len().max(1))
        .collect();
    let outcomes =
        supervise::supervise_fleet(&plan.shards, &mut pool, &sup_opts, |ev| {
            if !quiet {
                crate::logging::info("orchestrator", describe(ev));
            }
            let mut fields = shard_event_fields(ev);
            if let Some(names) = &host_names {
                if let ShardEventKind::Reassigned { to_host, .. } = &ev.kind {
                    if let Some(h) = names.iter().position(|n| n == to_host) {
                        host_of[ev.shard] = h;
                    }
                }
                // HostLost already carries its own host field
                if !matches!(ev.kind, ShardEventKind::HostLost { .. }) {
                    fields.push(("host", json::s(names[host_of[ev.shard]].clone())));
                }
            }
            elog.emit(ev.kind.tag(), fields);
            events.push(ev.clone());
            if watch_enabled {
                for alert in watchdog.scan(&events_path) {
                    crate::logging::warn("watchdog", &alert.message);
                    elog.emit(alert.kind, alert.fields);
                }
            }
        })?;
    let planned_kills = opts
        .fault_plan
        .as_ref()
        .map_or(0, |p| p.kills.len());
    if planned_kills > 0
        && outcomes.iter().all(|o| o.chaos_kills == 0)
        && !quiet
    {
        crate::logging::warn(
            "orchestrator",
            "chaos drill never fired: the fleet completed before a strike \
             window opened (grid too small/fast for the kill specs)",
        );
    }

    let merge = merge::merge_and_finish(
        cfg,
        &plan,
        &opts.dir,
        &prior_state,
        opts.trace_cache_global.as_deref(),
    )?;
    elog.emit(
        "merge_done",
        vec![
            ("resumed", json::num(merge.resumed as f64)),
            ("healed", json::num(merge.healed as f64)),
            ("covered", json::num(merge.audit.present as f64)),
            ("planned", json::num(merge.audit.planned as f64)),
            ("records", json::num(merge.compact_stats.records_out as f64)),
        ],
    );
    // final watchdog pass: catch-up events (degraded cells, healing
    // churn) land after the last supervision callback
    if watch_enabled {
        for alert in watchdog.scan(&events_path) {
            crate::logging::warn("watchdog", &alert.message);
            elog.emit(alert.kind, alert.fields);
        }
    }
    if !quiet {
        crate::logging::info(
            "orchestrator",
            format!(
                "merged {} resumed + {} healed scenarios; coverage {}/{}; \
                 compacted {} record(s) -> {}",
                merge.resumed,
                merge.healed,
                merge.audit.present,
                merge.audit.planned,
                merge.compact_stats.records_out,
                merge.compacted.display()
            ),
        );
    }
    Ok(LaunchReport { plan, outcomes, events, merge })
}
