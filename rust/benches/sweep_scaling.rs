//! `cargo bench --bench sweep_scaling` — throughput of the sweep
//! engine on the paper's 24-scenario comparison grid (2 models × 3
//! methods × 4 seeds), comparing four execution modes:
//!
//! * **legacy** — the pre-trace-sharing path: every scenario draws its
//!   own routing trace (`sweep::run_sweep_legacy`);
//! * **unfused** — one trace per (model, seed) cell, one full
//!   evaluation pass per method (`--unfused`, the pre-fusion
//!   trace-shared engine); pinned bit-identical to legacy;
//! * **fused** — one trace per cell AND one trace walk evaluating all
//!   methods simultaneously (`sim::evaluate_cell`, the default);
//!   pinned bit-identical to both. All three draw with the **default
//!   splitting sampler** (the trace-provenance flip);
//! * **fused_seq** — the pre-flip sequential sampler (`--router seq`;
//!   same distribution, different sample, hash-distinct).
//!
//! Also measures the `--rng v2` counter-based generator (`rng2_*`
//! rows): the paper grid end to end, and a single dominant cell where
//! the intra-cell iteration splitter engages at 8 workers —
//! byte-identity across the split re-asserted. And micro-benches the
//! trace stage (cold-vs-warm trace cache
//! through the store, byte-identity re-asserted), the chunked batch
//! samplers against their scalar per-draw paths (gamma and normal —
//! pinned bit-identical elsewhere, measured here), the multinomial
//! samplers on paper-scale draws, the method-evaluation stage in
//! isolation (fused vs unfused on pre-drawn traces), and the pool
//! runtime in isolation (shared injector vs work-stealing on a
//! heavy-tailed synthetic grid — `steal_*` and `tail_latency_*` rows
//! per worker count), exercises the sidecar telemetry plane (an
//! instrumented cold+warm cached sweep with the event log on; the
//! merged metrics registry is folded into the artifact as the
//! `telemetry` object plus flat `telemetry_*` / `stage_*` rows),
//! and re-asserts the determinism contract (every
//! worker count, every mode, and every pool/channel/pinning knob must
//! emit the serial legacy run's exact bytes).
//!
//! Writes `BENCH_sweep.json` (scenarios/sec per mode × worker count,
//! end-to-end / eval-stage / trace-stage speedups, sampler draws/sec)
//! so the perf trajectory is tracked PR-over-PR.

use std::time::Instant;

use memfine::bench::{fmt_time, BenchReport};
use memfine::config::SweepConfig;
use memfine::json::{self, Value};
use memfine::sim;
use memfine::sweep::{self, SweepRunOptions};
use memfine::trace::{RngVersion, RouterSampler, SharedRoutingTrace};
use memfine::util::rng::Rng;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn scenarios_per_sec(n: usize, wall: f64) -> f64 {
    n as f64 / wall.max(1e-9)
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Legacy,
    Unfused,
    Fused,
    FusedSeq,
}

/// Time one sweep invocation, returning (wall seconds, pretty JSON).
fn timed_run(cfg: &SweepConfig, workers: usize, mode: Mode) -> (f64, String) {
    let t0 = Instant::now();
    let report = match mode {
        Mode::Legacy => sweep::run_sweep_legacy(cfg, workers).expect("legacy sweep"),
        Mode::Unfused => {
            let opts = SweepRunOptions { workers, unfused: true, ..Default::default() };
            sweep::run_sweep_with(cfg, &opts).expect("unfused sweep").report
        }
        Mode::Fused => {
            let opts = SweepRunOptions { workers, ..Default::default() };
            sweep::run_sweep_with(cfg, &opts).expect("fused sweep").report
        }
        Mode::FusedSeq => {
            let opts = SweepRunOptions {
                workers,
                sampler: RouterSampler::Sequential,
                ..Default::default()
            };
            sweep::run_sweep_with(cfg, &opts).expect("fused seq sweep").report
        }
    };
    (t0.elapsed().as_secs_f64(), report.to_json().to_string_pretty())
}

/// The method-evaluation stage in isolation: evaluate one cell's
/// methods against an already-drawn trace, fused vs per-method.
/// Returns (unfused scn/s, fused scn/s) — the stage the fusion
/// accelerates, with the trace-generation cost both modes share
/// factored out.
fn eval_stage_micro(cfg: &SweepConfig) -> (f64, f64) {
    let cells = sweep::expand_cells(cfg).expect("cells");
    let traces: Vec<SharedRoutingTrace> = cells
        .iter()
        .map(|cell| {
            let run = &cell.scenarios[0].run;
            let gating = memfine::router::GatingSim::new(
                run.model.clone(),
                run.parallel.clone(),
                run.seed,
            )
            .with_sampler(RouterSampler::default());
            SharedRoutingTrace::generate(&gating, run.iterations)
        })
        .collect();
    let reps = 20;
    let n = (cfg.scenario_count() * reps) as f64;

    let t0 = Instant::now();
    let mut acc = 0u64;
    for _ in 0..reps {
        for (cell, trace) in cells.iter().zip(&traces) {
            for sc in &cell.scenarios {
                acc += sim::run_scenario_on_trace(&sc.run, sc.method.clone(), trace)
                    .expect("unfused eval")
                    .oom_iterations;
            }
        }
    }
    let unfused = n / t0.elapsed().as_secs_f64().max(1e-9);

    let t0 = Instant::now();
    for _ in 0..reps {
        for (cell, trace) in cells.iter().zip(&traces) {
            let methods: Vec<_> =
                cell.scenarios.iter().map(|sc| sc.method.clone()).collect();
            for out in sim::evaluate_cell(&cell.scenarios[0].run, &methods, trace)
                .expect("fused eval")
            {
                acc += out.summary.oom_iterations;
            }
        }
    }
    let fused = n / t0.elapsed().as_secs_f64().max(1e-9);
    assert!(acc > 0, "keep the evaluations observable");
    (unfused, fused)
}

/// The trace stage through the on-disk store: a serial sweep with a
/// cold cache (draws + saves every cell) vs the same sweep warm
/// (loads every cell). Byte-identity is re-asserted; the wall-clock
/// gap is the trace-generation share the cache removes.
fn trace_stage_micro(cfg: &SweepConfig) -> (f64, f64) {
    let mut dir = std::env::temp_dir();
    dir.push(format!("memfine-bench-trace-cache-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let opts = SweepRunOptions {
        workers: 1,
        trace_cache: Some(dir.clone()),
        ..Default::default()
    };
    let t0 = Instant::now();
    let cold = sweep::run_sweep_with(cfg, &opts).expect("cold cached sweep");
    let cold_s = t0.elapsed().as_secs_f64();
    assert_eq!(cold.traces_cached, 0, "first cached run must be cold");
    let t0 = Instant::now();
    let warm = sweep::run_sweep_with(cfg, &opts).expect("warm cached sweep");
    let warm_s = t0.elapsed().as_secs_f64();
    assert_eq!(warm.traces_generated, 0, "second cached run must be warm");
    assert_eq!(
        cold.report.to_json().to_string_pretty(),
        warm.report.to_json().to_string_pretty(),
        "warm-cache sweep diverged from the cold bytes"
    );
    std::fs::remove_dir_all(&dir).ok();
    (cold_s, warm_s)
}

/// The chunked batch samplers against their scalar per-draw paths
/// (which they are pinned bit-identical to): gamma at the routing
/// regime's boost shape over 256 experts, and raw normals. Returns
/// (gamma scalar draws/s, gamma batch draws/s, normal scalar draws/s,
/// normal batch draws/s).
fn batch_sampler_micro() -> (f64, f64, f64, f64) {
    let shape = 0.02; // deep-layer chaos concentration: the boost path
    let n = 256;
    let reps = 2_000;
    let total = (n * reps) as f64;
    let mut buf = vec![0.0f64; n];
    let mut acc = 0.0f64;

    let t0 = Instant::now();
    let mut rng = Rng::new(11);
    for _ in 0..reps {
        for slot in buf.iter_mut() {
            *slot = rng.gamma(shape);
        }
        acc += buf[n - 1];
    }
    let gamma_scalar = total / t0.elapsed().as_secs_f64().max(1e-9);

    let t0 = Instant::now();
    let mut rng = Rng::new(11);
    for _ in 0..reps {
        rng.gamma_batch(shape, &mut buf);
        acc += buf[n - 1];
    }
    let gamma_batch = total / t0.elapsed().as_secs_f64().max(1e-9);

    let t0 = Instant::now();
    let mut rng = Rng::new(12);
    for _ in 0..reps {
        for slot in buf.iter_mut() {
            *slot = rng.normal();
        }
        acc += buf[n - 1];
    }
    let normal_scalar = total / t0.elapsed().as_secs_f64().max(1e-9);

    let t0 = Instant::now();
    let mut rng = Rng::new(12);
    for _ in 0..reps {
        rng.normal_batch(&mut buf);
        acc += buf[n - 1];
    }
    let normal_batch = total / t0.elapsed().as_secs_f64().max(1e-9);

    assert!(acc.is_finite(), "keep the draws observable");
    (gamma_scalar, gamma_batch, normal_scalar, normal_batch)
}

/// One synthetic pool job: a deterministic xorshift spin whose cost
/// is heavily skewed (every 8th job ~50× the base) so stragglers
/// dominate unless the runtime rebalances.
fn pool_job(x: u64) -> u64 {
    let spins = if x % 8 == 0 { 500_000 } else { 10_000 };
    let mut acc = x.wrapping_mul(0x9e3779b97f4a7c15) | 1;
    for _ in 0..spins {
        acc ^= acc << 13;
        acc ^= acc >> 7;
        acc ^= acc << 17;
    }
    acc
}

/// The pool runtime in isolation: 256 skewed synthetic jobs, shared
/// injector vs work-stealing at each worker count. Output equality
/// with the serial run is asserted; the emitted `pool_*`, `steal_*`
/// and `tail_latency_*` rows track steal traffic and straggler
/// overhead PR-over-PR.
fn pool_stage_micro(rows: &mut Vec<(String, Value)>) {
    use memfine::sweep::pool::{self, PoolConfig, Schedule};
    let items: Vec<u64> = (0..256).collect();
    let serial_cfg = PoolConfig::with_workers(1);
    let (serial, _) =
        pool::parallel_map_indexed_with(items.clone(), &serial_cfg, |_, x| pool_job(x));
    let mut table = BenchReport::new(
        "pool runtime — injector vs stealing, 256 skewed jobs (every 8th ~50x)",
        &["schedule", "workers", "wall clock", "steals ok/try", "tail latency"],
    );
    for &schedule in &[Schedule::Injector, Schedule::Stealing] {
        for &workers in &WORKER_COUNTS {
            let cfg = PoolConfig { workers, schedule, ..PoolConfig::default() };
            let (out, stats) =
                pool::parallel_map_indexed_with(items.clone(), &cfg, |_, x| pool_job(x));
            assert_eq!(
                out,
                serial,
                "pool {}/{workers}w diverged from the serial outputs",
                schedule.tag()
            );
            let tag = stats.schedule.tag();
            let wall_s = stats.wall_ns as f64 / 1e9;
            let tail_s = stats.tail_latency_ns() as f64 / 1e9;
            rows.push((format!("pool_{tag}_{workers}w_wall_s"), json::num(wall_s)));
            rows.push((
                format!("steal_attempts_{tag}_{workers}w"),
                json::num(stats.steals_attempted() as f64),
            ));
            rows.push((
                format!("steal_successes_{tag}_{workers}w"),
                json::num(stats.steals_succeeded() as f64),
            ));
            rows.push((format!("tail_latency_{tag}_{workers}w_s"), json::num(tail_s)));
            table.row(&[
                tag.to_string(),
                workers.to_string(),
                fmt_time(wall_s),
                format!("{}/{}", stats.steals_succeeded(), stats.steals_attempted()),
                fmt_time(tail_s),
            ]);
        }
    }
    table.print();
}

/// The v2 counter-based generator end to end: the paper grid under
/// `--rng v2` (a different, hash-distinct sample — its bytes compare
/// only against itself), and a single dominant cell where the
/// intra-cell splitter actually engages — serial whole-cell vs 8
/// workers cutting the cell into iteration-range jobs. Byte-identity
/// across the split is re-asserted; the wall-clock gap is the
/// straggler tail the splitter removes. Returns (grid serial s, grid
/// 8w s, single-cell unsplit s, single-cell split 8w s).
fn rng2_stage_micro(cfg: &SweepConfig) -> (f64, f64, f64, f64) {
    let t0 = Instant::now();
    let serial = sweep::run_sweep_with(
        cfg,
        &SweepRunOptions { workers: 1, rng: RngVersion::V2, ..Default::default() },
    )
    .expect("v2 serial sweep");
    let v2_serial_s = t0.elapsed().as_secs_f64();
    let v2_json = serial.report.to_json().to_string_pretty();
    let t0 = Instant::now();
    let wide = sweep::run_sweep_with(
        cfg,
        &SweepRunOptions { workers: 8, rng: RngVersion::V2, ..Default::default() },
    )
    .expect("v2 8-worker sweep");
    let v2_8w_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        v2_json,
        wide.report.to_json().to_string_pretty(),
        "v2 8-worker sweep diverged from its serial bytes"
    );

    // One dominant cell — the shape whole-cell scheduling cannot
    // parallelise at all, and the only place intra-cell splitting can
    // win wall-clock.
    let single = SweepConfig {
        models: vec![cfg.models[0].clone()],
        methods: cfg.methods.clone(),
        seeds: vec![cfg.seeds[0]],
        iterations: cfg.iterations * 8,
    };
    let t0 = Instant::now();
    let whole = sweep::run_sweep_with(
        &single,
        &SweepRunOptions { workers: 1, rng: RngVersion::V2, ..Default::default() },
    )
    .expect("v2 single-cell serial sweep");
    let unsplit_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let split = sweep::run_sweep_with(
        &single,
        &SweepRunOptions { workers: 8, rng: RngVersion::V2, ..Default::default() },
    )
    .expect("v2 single-cell split sweep");
    let split_s = t0.elapsed().as_secs_f64();
    assert!(
        split.pool.jobs_total() > 1,
        "the dominant cell must auto-split at 8 workers"
    );
    assert_eq!(
        whole.report.to_json().to_string_pretty(),
        split.report.to_json().to_string_pretty(),
        "intra-cell split diverged from the whole-cell bytes"
    );
    (v2_serial_s, v2_8w_s, unsplit_s, split_s)
}

/// The sidecar telemetry plane through the bench: an instrumented
/// cached sweep (cold, then warm) with the event log on, per-run
/// registries merged into one exposition — cache traffic counters,
/// backpressure, and the per-stage timing histograms' summary stats
/// all land in the artifact. Returns the merged registry's JSON.
fn telemetry_stage_micro(cfg: &SweepConfig, rows: &mut Vec<(String, Value)>) -> Value {
    let mut dir = std::env::temp_dir();
    dir.push(format!("memfine-bench-telemetry-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).expect("telemetry bench dir");
    let events = dir.join("events.jsonl");
    let opts = SweepRunOptions {
        workers: 2,
        trace_cache: Some(dir.join("trace-cache")),
        events: Some(events.clone()),
        ..Default::default()
    };
    let cold = sweep::run_sweep_with(cfg, &opts).expect("cold instrumented sweep");
    let warm = sweep::run_sweep_with(cfg, &opts).expect("warm instrumented sweep");
    let mut merged = cold.metrics.clone();
    merged.merge(&warm.metrics);
    let cells = cold.traces_generated as u64;
    assert_eq!(merged.counter("trace.generated"), cells, "cold run draws every cell");
    assert_eq!(merged.counter("trace.cached"), cells, "warm run reuses every cell");
    let (evs, torn) = memfine::obs::read_events(&events).expect("read event log");
    assert_eq!(torn, 0, "clean runs leave no torn event lines");
    rows.push(("telemetry_trace_generated".into(), json::num(cells as f64)));
    rows.push((
        "telemetry_trace_degraded".into(),
        json::num(merged.counter("trace.degraded") as f64),
    ));
    rows.push((
        "telemetry_blocked_sends".into(),
        json::num(merged.counter("pool.blocked_sends") as f64),
    ));
    rows.push((
        "telemetry_events_dropped".into(),
        json::num(merged.counter("events.dropped") as f64),
    ));
    rows.push(("telemetry_event_lines".into(), json::num(evs.len() as f64)));
    for stage in ["stage.trace_ns", "stage.eval_ns"] {
        if let Some(h) = merged.histogram(stage) {
            let key = stage.replace('.', "_");
            rows.push((format!("{key}_p50"), json::num(h.quantile(0.5) as f64)));
            rows.push((format!("{key}_p99"), json::num(h.quantile(0.99) as f64)));
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    merged.to_json()
}

fn multinomial_micro() -> (f64, f64) {
    // paper-scale draw: 2^20 token copies over 256 experts with the
    // deep-layer chaos-peak popularity shape
    let probs = Rng::new(7).dirichlet_symmetric(0.02, 256);
    let n = 1u64 << 20;
    let reps = 400;
    let t0 = Instant::now();
    let mut acc = 0u64;
    let mut rng = Rng::new(42);
    for _ in 0..reps {
        acc += rng.multinomial(n, &probs)[0];
    }
    let seq = reps as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut rng = Rng::new(42);
    for _ in 0..reps {
        acc += rng.multinomial_split(n, &probs)[0];
    }
    let split = reps as f64 / t0.elapsed().as_secs_f64();
    assert!(acc > 0, "keep the draws observable");
    (seq, split)
}

fn main() {
    memfine::logging::init();
    let cfg = SweepConfig::paper_grid(7, 4, 10);
    let n = cfg.scenario_count();
    println!(
        "grid: {} scenarios ({} iterations each), host parallelism {}",
        n,
        cfg.iterations,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    // Warm-up (first run pays allocator/page-cache costs).
    sweep::run_sweep(&cfg, 1).expect("warmup sweep");

    let (legacy_serial_s, legacy_json) = timed_run(&cfg, 1, Mode::Legacy);

    let mut report = BenchReport::new(
        "sweep scaling — legacy vs trace-shared (unfused) vs fused vs fused+seq-router",
        &["mode", "workers", "wall clock", "scn/s", "vs legacy serial", "bit-identical"],
    );
    let mut artifact_rows: Vec<(String, Value)> = Vec::new();
    let mut record = |mode: &str, workers: usize, wall: f64, identical: Option<bool>| {
        artifact_rows.push((
            format!("{mode}_{workers}w_scenarios_per_sec"),
            json::num(scenarios_per_sec(n, wall)),
        ));
        (
            mode.to_string(),
            workers.to_string(),
            fmt_time(wall),
            format!("{:.1}", scenarios_per_sec(n, wall)),
            format!("{:.2}x", legacy_serial_s / wall),
            match identical {
                None => "n/a (different sample)".to_string(),
                Some(true) => "yes".to_string(),
                Some(false) => "NO".to_string(),
            },
        )
    };

    let mut unfused_serial_s = f64::NAN;
    let mut fused_serial_s = f64::NAN;
    let mut fused_2w_s = f64::NAN;
    let mut fused_seq_serial_s = f64::NAN;
    for &workers in &WORKER_COUNTS {
        let (wall, jsn) = if workers == 1 {
            (legacy_serial_s, legacy_json.clone())
        } else {
            timed_run(&cfg, workers, Mode::Legacy)
        };
        let identical = jsn == legacy_json;
        assert!(identical, "legacy workers={workers} diverged from serial bytes");
        let row = record("legacy", workers, wall, Some(identical));
        report.row(&[row.0, row.1, row.2, row.3, row.4, row.5]);
    }
    for &workers in &WORKER_COUNTS {
        let (wall, jsn) = timed_run(&cfg, workers, Mode::Unfused);
        if workers == 1 {
            unfused_serial_s = wall;
        }
        let identical = jsn == legacy_json;
        assert!(identical, "trace sharing workers={workers} diverged from legacy bytes");
        let row = record("unfused", workers, wall, Some(identical));
        report.row(&[row.0, row.1, row.2, row.3, row.4, row.5]);
    }
    for &workers in &WORKER_COUNTS {
        let (wall, jsn) = timed_run(&cfg, workers, Mode::Fused);
        if workers == 1 {
            fused_serial_s = wall;
        }
        if workers == 2 {
            fused_2w_s = wall;
        }
        let identical = jsn == legacy_json;
        assert!(identical, "fused workers={workers} diverged from legacy bytes");
        let row = record("fused", workers, wall, Some(identical));
        report.row(&[row.0, row.1, row.2, row.3, row.4, row.5]);
    }
    let mut seq_json: Option<String> = None;
    for &workers in &WORKER_COUNTS {
        let (wall, jsn) = timed_run(&cfg, workers, Mode::FusedSeq);
        if workers == 1 {
            fused_seq_serial_s = wall;
        }
        // the sequential sampler is its own deterministic sample:
        // identical across worker counts, different from the default
        match &seq_json {
            None => seq_json = Some(jsn),
            Some(first) => assert_eq!(
                first, &jsn,
                "seq-router workers={workers} diverged from its serial bytes"
            ),
        }
        let row = record("fused_seq", workers, wall, None);
        report.row(&[row.0, row.1, row.2, row.3, row.4, row.5]);
    }
    // Orchestrated: the same grid as a supervised 2-process fleet of
    // real `memfine sweep` children (2 workers each) through the full
    // launch → supervise → merge → audit → compact path. Measures the
    // process-orchestration overhead against the in-process 2-worker
    // run; bytes must still match exactly.
    let orchestrated_2p_s = {
        let mut dir = std::env::temp_dir();
        dir.push(format!("memfine-bench-launch-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut lcfg = memfine::config::LaunchConfig::new(cfg.clone());
        lcfg.procs = 2;
        lcfg.workers_per_proc = 2;
        lcfg.poll_ms = 20;
        let mut opts = memfine::orchestrator::LaunchOptions::new(dir.clone());
        opts.binary = Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_memfine")));
        opts.quiet = true;
        let t0 = Instant::now();
        let launched = memfine::orchestrator::launch(&lcfg, &opts).expect("orchestrated launch");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(
            launched.merge.report.to_json().to_string_pretty(),
            legacy_json,
            "orchestrated launch diverged from the in-process bytes"
        );
        std::fs::remove_dir_all(&dir).ok();
        wall
    };
    {
        let row = record("orchestrated", 2, orchestrated_2p_s, Some(true));
        report.row(&[row.0, row.1, row.2, row.3, row.4, row.5]);
    }
    report.print();

    // The pool knobs are execution-only: the fused sweep under the old
    // shared-injector schedule, the unbounded std channel, and core
    // pinning must all reproduce the legacy bytes exactly.
    for (pool, channel, pin_cores) in [
        (sweep::Schedule::Injector, sweep::ChannelKind::Bounded, false),
        (sweep::Schedule::Stealing, sweep::ChannelKind::StdMpsc, true),
    ] {
        let opts = SweepRunOptions {
            workers: 8,
            pool,
            channel,
            pin_cores,
            ..Default::default()
        };
        let jsn = sweep::run_sweep_with(&cfg, &opts)
            .expect("pool-knob sweep")
            .report
            .to_json()
            .to_string_pretty();
        assert_eq!(
            jsn,
            legacy_json,
            "pool {}/{} pin={pin_cores} diverged from the legacy bytes",
            pool.tag(),
            channel.tag()
        );
    }

    pool_stage_micro(&mut artifact_rows);

    let (rng2_serial_s, rng2_8w_s, rng2_unsplit_s, rng2_split_s) = rng2_stage_micro(&cfg);

    let (seq_dps, split_dps) = multinomial_micro();
    let (gamma_scalar_dps, gamma_batch_dps, normal_scalar_dps, normal_batch_dps) =
        batch_sampler_micro();
    let (trace_cold_s, trace_warm_s) = trace_stage_micro(&cfg);
    let (eval_unfused_sps, eval_fused_sps) = eval_stage_micro(&cfg);
    let telemetry_doc = telemetry_stage_micro(&cfg, &mut artifact_rows);
    let sharing_speedup = legacy_serial_s / unfused_serial_s;
    let fusion_speedup = unfused_serial_s / fused_serial_s;
    let eval_fusion_speedup = eval_fused_sps / eval_unfused_sps;
    let warm_cache_speedup = trace_cold_s / trace_warm_s;
    let total_speedup = legacy_serial_s / fused_serial_s;
    println!(
        "\nmultinomial (2^20 copies, 256 experts, chaos-peak popularity): \
         sequential {seq_dps:.0} draws/s, split {split_dps:.0} draws/s ({:.2}x — \
         the default sampler since the provenance flip)",
        split_dps / seq_dps
    );
    println!(
        "batch samplers (chunked fixed-lane, pinned bit-identical to scalar): \
         gamma(0.02) {gamma_scalar_dps:.0} -> {gamma_batch_dps:.0} draws/s ({:.2}x), \
         normal {normal_scalar_dps:.0} -> {normal_batch_dps:.0} draws/s ({:.2}x)",
        gamma_batch_dps / gamma_scalar_dps,
        normal_batch_dps / normal_scalar_dps,
    );
    println!(
        "trace stage (serial sweep through the on-disk store): cold {} \
         ({:.1} scn/s) -> warm {} ({:.1} scn/s), {warm_cache_speedup:.2}x — \
         byte-identical artifacts",
        fmt_time(trace_cold_s),
        scenarios_per_sec(n, trace_cold_s),
        fmt_time(trace_warm_s),
        scenarios_per_sec(n, trace_warm_s),
    );
    println!(
        "serial scenarios/sec: legacy {:.1} → trace-shared {:.1} ({sharing_speedup:.2}x) \
         → fused {:.1} ({fusion_speedup:.2}x on top, {total_speedup:.2}x total); \
         seq-router reference {:.1}",
        scenarios_per_sec(n, legacy_serial_s),
        scenarios_per_sec(n, unfused_serial_s),
        scenarios_per_sec(n, fused_serial_s),
        scenarios_per_sec(n, fused_seq_serial_s),
    );
    println!(
        "method-evaluation stage (pre-drawn traces, 3 methods/cell): \
         unfused {eval_unfused_sps:.0} scn/s → fused {eval_fused_sps:.0} scn/s \
         ({eval_fusion_speedup:.2}x)",
    );
    println!(
        "orchestrated 2-proc launch: {} vs in-process 2-worker {} \
         ({:.2}x overhead; spawn + supervise + merge + audit + compact)",
        fmt_time(orchestrated_2p_s),
        fmt_time(fused_2w_s),
        orchestrated_2p_s / fused_2w_s,
    );
    println!(
        "rng v2 (counter-based Philox, --rng v2): grid serial {} -> 8 workers {} \
         ({:.2}x); dominant single cell {} -> intra-cell split at 8 workers {} \
         ({:.2}x) — byte-identical across every split",
        fmt_time(rng2_serial_s),
        fmt_time(rng2_8w_s),
        rng2_serial_s / rng2_8w_s,
        fmt_time(rng2_unsplit_s),
        fmt_time(rng2_split_s),
        rng2_unsplit_s / rng2_split_s,
    );
    println!("\nreading: cells share one routed-token stream across methods AND walk it");
    println!("once for all methods; the splitting multinomial (now the default, with");
    println!("provenance recorded everywhere) cheapens the one remaining draw, and the");
    println!("trace store removes it entirely on re-sweeps. Output bytes never depend");
    println!("on schedule, worker count, shard split, resume point or cache state.");

    let mut fields = vec![
        ("grid_scenarios", json::num(n as f64)),
        ("grid_iterations", json::num(cfg.iterations as f64)),
        ("legacy_serial_s", json::num(legacy_serial_s)),
        ("unfused_serial_s", json::num(unfused_serial_s)),
        ("fused_serial_s", json::num(fused_serial_s)),
        ("fused_seq_serial_s", json::num(fused_seq_serial_s)),
        ("speedup_trace_sharing", json::num(sharing_speedup)),
        ("speedup_fused_vs_unfused", json::num(fusion_speedup)),
        ("speedup_total", json::num(total_speedup)),
        ("eval_stage_unfused_scn_per_sec", json::num(eval_unfused_sps)),
        ("eval_stage_fused_scn_per_sec", json::num(eval_fused_sps)),
        ("eval_stage_fused_speedup", json::num(eval_fusion_speedup)),
        ("trace_stage_cold_s", json::num(trace_cold_s)),
        ("trace_stage_warm_s", json::num(trace_warm_s)),
        ("trace_stage_warm_cache_speedup", json::num(warm_cache_speedup)),
        ("multinomial_seq_draws_per_sec", json::num(seq_dps)),
        ("multinomial_split_draws_per_sec", json::num(split_dps)),
        ("multinomial_split_speedup", json::num(split_dps / seq_dps)),
        ("gamma_scalar_draws_per_sec", json::num(gamma_scalar_dps)),
        ("gamma_batch_draws_per_sec", json::num(gamma_batch_dps)),
        (
            "gamma_batch_speedup",
            json::num(gamma_batch_dps / gamma_scalar_dps),
        ),
        ("normal_scalar_draws_per_sec", json::num(normal_scalar_dps)),
        ("normal_batch_draws_per_sec", json::num(normal_batch_dps)),
        (
            "normal_batch_speedup",
            json::num(normal_batch_dps / normal_scalar_dps),
        ),
        ("orchestrated_2procs_s", json::num(orchestrated_2p_s)),
        ("inprocess_2workers_s", json::num(fused_2w_s)),
        (
            "orchestrated_overhead_vs_inprocess",
            json::num(orchestrated_2p_s / fused_2w_s),
        ),
        ("rng2_fused_serial_s", json::num(rng2_serial_s)),
        ("rng2_fused_8w_s", json::num(rng2_8w_s)),
        (
            "rng2_fused_serial_scenarios_per_sec",
            json::num(scenarios_per_sec(n, rng2_serial_s)),
        ),
        ("rng2_singlecell_unsplit_s", json::num(rng2_unsplit_s)),
        ("rng2_singlecell_split_8w_s", json::num(rng2_split_s)),
        (
            "rng2_intracell_split_speedup",
            json::num(rng2_unsplit_s / rng2_split_s),
        ),
        ("determinism_rng2_split_vs_serial", Value::Bool(true)),
        ("determinism_pool_knobs", Value::Bool(true)),
        ("determinism_legacy_vs_shared", Value::Bool(true)),
        ("determinism_fused_vs_unfused", Value::Bool(true)),
        ("determinism_orchestrated_vs_inprocess", Value::Bool(true)),
        ("determinism_warm_cache_vs_cold", Value::Bool(true)),
        // the merged cold+warm registry exposition (counters, gauges,
        // stage histograms) — the campaign-mergeable telemetry view
        ("telemetry", telemetry_doc),
    ];
    fields.extend(artifact_rows.iter().map(|(k, v)| (k.as_str(), v.clone())));
    let doc = json::obj(fields);
    std::fs::write("BENCH_sweep.json", format!("{}\n", doc.to_string_pretty()))
        .expect("write BENCH_sweep.json");
    println!("\nartifact written to BENCH_sweep.json");
}
