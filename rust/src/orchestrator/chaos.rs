//! Scripted, seeded fault injection for launch campaigns.
//!
//! A [`FaultPlan`] is a deterministic drill script: kill storms at
//! chosen supervision ticks, mid-file checkpoint corruption (overwrite
//! a middle record, or truncate the tail), injected IO errors through
//! the [`crate::faultfs`] seam (scoped to shard children or to the
//! supervisor process), and artificially slow shard startups. Plans
//! come from three places, in precedence order: an explicit JSON plan
//! file (`memfine launch --chaos-plan drill.json`), a seed
//! (`--chaos-seed N`, expanded deterministically from the seed and the
//! campaign directory by [`FaultPlan::from_seed`]), or the legacy
//! one-shot `--chaos-kill` flag ([`FaultPlan::kill_one`]).
//!
//! The plan only *schedules* faults; the supervisor's poll loop
//! executes kill and corruption specs (see
//! [`super::supervise`]), and `launch` arms the IO specs. Every drill
//! must end with a merged artifact byte-identical to the undisturbed
//! single-process sweep — that is the invariant the chaos matrix in CI
//! asserts.
//!
//! Plan-file format (all fields optional):
//!
//! ```json
//! {
//!   "seed": 7,
//!   "kills":   [{"at_poll": 2}, {"at_poll": 6, "shard": 1}],
//!   "corrupt": [{"at_poll": 4, "shard": 0, "mode": "middle"},
//!               {"at_poll": 9, "shard": 2, "mode": "truncate", "bytes": 17}],
//!   "slow":    [{"shard": 1, "delay_ms": 50}],
//!   "io":      [{"site": "checkpoint", "kind": "enospc", "count": 1,
//!                "scope": "children"}],
//!   "host_loss": [{"at_poll": 3, "host": 1}]
//! }
//! ```

use std::path::Path;

use crate::error::{Error, Result};
use crate::faultfs::FaultKind;
use crate::json::{self, Value};
use crate::util;

/// Kill one shard child at (or after) a supervision poll tick. With
/// `shard: None` the victim is chosen by the legacy chaos heuristic:
/// the first child with observed checkpoint progress, falling back to
/// any running child once at least three polls have elapsed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KillSpec {
    pub at_poll: u64,
    pub shard: Option<usize>,
}

/// How to damage a checkpoint file in flight.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CorruptMode {
    /// Overwrite a complete middle record line (never the header,
    /// never the last line) with same-length garbage — the
    /// skip-and-count path of the checkpoint reader must absorb it.
    MiddleRecord,
    /// Truncate the file by `bytes` from the end — the torn-tail path.
    TruncateTail { bytes: u64 },
}

impl CorruptMode {
    pub fn tag(&self) -> &'static str {
        match self {
            CorruptMode::MiddleRecord => "middle",
            CorruptMode::TruncateTail { .. } => "truncate",
        }
    }
}

/// Damage `shard`'s checkpoint at (or after) a poll tick. The spec
/// stays pending until the file has enough content to damage; shard
/// indices are taken modulo the fleet size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorruptSpec {
    pub at_poll: u64,
    pub shard: usize,
    pub mode: CorruptMode,
}

/// Delay `shard`'s first spawn by `delay_ms` — a slow host.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlowSpec {
    pub shard: usize,
    pub delay_ms: u64,
}

/// Which process(es) an IO fault spec arms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoScope {
    /// Armed (via [`crate::faultfs::FAULT_ENV`]) in every shard
    /// child's *first* spawn; relaunches run clean.
    Children,
    /// Armed in the launching process itself (the merge catch-up
    /// path runs here — expect loud failures, not silent healing).
    Supervisor,
}

impl IoScope {
    pub fn tag(self) -> &'static str {
        match self {
            IoScope::Children => "children",
            IoScope::Supervisor => "supervisor",
        }
    }
}

/// Arm `count` IO faults of `kind` on a [`crate::faultfs`] site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IoFaultSpec {
    pub site: String,
    pub kind: FaultKind,
    pub count: u64,
    pub scope: IoScope,
}

/// Lose a whole host at (or after) a supervision poll tick: every
/// child assigned to `host` is killed *and* the host's lease stops
/// renewing, so the supervisor must detect the expiry and reassign
/// the shards to survivors. Host indices are taken modulo the host
/// count. Only meaningful on a multi-host launch with a lease plane;
/// a single-host launch drops the spec with a warning (nothing could
/// ever declare the loss).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostLossSpec {
    pub at_poll: u64,
    pub host: usize,
}

/// A complete drill script. See the module docs for the file format.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub kills: Vec<KillSpec>,
    pub corrupt: Vec<CorruptSpec>,
    pub slow: Vec<SlowSpec>,
    pub io: Vec<IoFaultSpec>,
    pub host_loss: Vec<HostLossSpec>,
}

/// splitmix64 finalizer — the plan generator's only mixing primitive.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// The legacy `--chaos-kill` drill: one heuristic kill, armed from
    /// the first poll.
    pub fn kill_one() -> FaultPlan {
        FaultPlan {
            kills: vec![KillSpec {
                at_poll: 0,
                shard: None,
            }],
            ..FaultPlan::default()
        }
    }

    /// Expand a seed into a full drill, deterministically in (seed,
    /// campaign dir): a two-kill storm early in supervision, one
    /// mid-file record corruption, and two ENOSPC charges on every
    /// child's streaming checkpoint writer — two because the
    /// degradation ladder retries a record write once in place, so a
    /// single charge is masked as a transient and never degrades.
    /// Same seed + same dir = same drill, so a failed drill replays
    /// exactly.
    pub fn from_seed(seed: u64, dir: &Path) -> FaultPlan {
        let h0 = util::fnv1a_64_update(
            util::fnv1a_64(dir.to_string_lossy().as_bytes()),
            &seed.to_le_bytes(),
        );
        let r1 = mix64(h0);
        let r2 = mix64(r1);
        let r3 = mix64(r2);
        let r4 = mix64(r3);
        FaultPlan {
            seed,
            kills: vec![
                KillSpec {
                    at_poll: 1 + r1 % 3,
                    shard: None,
                },
                KillSpec {
                    at_poll: 5 + r2 % 4,
                    shard: None,
                },
            ],
            corrupt: vec![CorruptSpec {
                at_poll: 2 + r3 % 3,
                shard: (r4 % 64) as usize,
                mode: CorruptMode::MiddleRecord,
            }],
            slow: Vec::new(),
            io: vec![IoFaultSpec {
                site: crate::faultfs::SITE_CHECKPOINT.to_string(),
                kind: FaultKind::Enospc,
                count: 2,
                scope: IoScope::Children,
            }],
            host_loss: Vec::new(),
        }
    }

    /// Whether the plan schedules anything at all.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.corrupt.is_empty()
            && self.slow.is_empty()
            && self.io.is_empty()
            && self.host_loss.is_empty()
    }

    /// The env-var value arming this plan's children-scoped IO specs
    /// (None if there are none). Format: `site:kind:count[,...]`.
    pub fn child_fault_env(&self) -> Option<String> {
        let entries: Vec<String> = self
            .io
            .iter()
            .filter(|s| s.scope == IoScope::Children)
            .map(|s| format!("{}:{}:{}", s.site, s.kind.tag(), s.count))
            .collect();
        if entries.is_empty() {
            None
        } else {
            Some(entries.join(","))
        }
    }

    /// Arm this plan's supervisor-scoped IO specs in-process.
    pub fn arm_supervisor_faults(&self) {
        for s in self.io.iter().filter(|s| s.scope == IoScope::Supervisor) {
            crate::faultfs::inject(&s.site, s.kind, s.count);
        }
    }

    pub fn to_json(&self) -> Value {
        let kills = self
            .kills
            .iter()
            .map(|k| {
                let mut rows = vec![("at_poll", json::num(k.at_poll as f64))];
                if let Some(s) = k.shard {
                    rows.push(("shard", json::num(s as f64)));
                }
                json::obj(rows)
            })
            .collect();
        let corrupt = self
            .corrupt
            .iter()
            .map(|c| {
                let mut rows = vec![
                    ("at_poll", json::num(c.at_poll as f64)),
                    ("shard", json::num(c.shard as f64)),
                    ("mode", json::s(c.mode.tag())),
                ];
                if let CorruptMode::TruncateTail { bytes } = c.mode {
                    rows.push(("bytes", json::num(bytes as f64)));
                }
                json::obj(rows)
            })
            .collect();
        let slow = self
            .slow
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("shard", json::num(s.shard as f64)),
                    ("delay_ms", json::num(s.delay_ms as f64)),
                ])
            })
            .collect();
        let io = self
            .io
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("site", json::s(&s.site)),
                    ("kind", json::s(s.kind.tag())),
                    ("count", json::num(s.count as f64)),
                    ("scope", json::s(s.scope.tag())),
                ])
            })
            .collect();
        let host_loss = self
            .host_loss
            .iter()
            .map(|h| {
                json::obj(vec![
                    ("at_poll", json::num(h.at_poll as f64)),
                    ("host", json::num(h.host as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("seed", json::num(self.seed as f64)),
            ("kills", json::arr(kills)),
            ("corrupt", json::arr(corrupt)),
            ("slow", json::arr(slow)),
            ("io", json::arr(io)),
            ("host_loss", json::arr(host_loss)),
        ])
    }

    /// Parse a plan file value. Every section is optional; unknown
    /// modes/kinds/scopes are config errors (a drill that silently
    /// drops a fault proves nothing).
    pub fn from_json(v: &Value) -> Result<FaultPlan> {
        let section = |key: &str| -> &[Value] {
            v.get(key).and_then(Value::as_arr).unwrap_or(&[])
        };
        let mut plan = FaultPlan {
            seed: v.get("seed").and_then(Value::as_u64).unwrap_or(0),
            ..FaultPlan::default()
        };
        for k in section("kills") {
            plan.kills.push(KillSpec {
                at_poll: k.req_u64("at_poll")?,
                shard: k.get("shard").and_then(Value::as_u64).map(|s| s as usize),
            });
        }
        for c in section("corrupt") {
            let mode = match c.req_str("mode")? {
                "middle" => CorruptMode::MiddleRecord,
                "truncate" => CorruptMode::TruncateTail {
                    bytes: c.get("bytes").and_then(Value::as_u64).unwrap_or(16),
                },
                other => {
                    return Err(Error::config(format!(
                        "unknown corrupt mode {other:?} (expected middle|truncate)"
                    )))
                }
            };
            plan.corrupt.push(CorruptSpec {
                at_poll: c.req_u64("at_poll")?,
                shard: c.req_u64("shard")? as usize,
                mode,
            });
        }
        for s in section("slow") {
            plan.slow.push(SlowSpec {
                shard: s.req_u64("shard")? as usize,
                delay_ms: s.req_u64("delay_ms")?,
            });
        }
        for s in section("io") {
            let kind_tag = s.req_str("kind")?;
            let kind = FaultKind::parse(kind_tag).ok_or_else(|| {
                Error::config(format!(
                    "unknown io fault kind {kind_tag:?} (expected enospc|eio)"
                ))
            })?;
            let scope = match s.get("scope").and_then(Value::as_str).unwrap_or("children") {
                "children" => IoScope::Children,
                "supervisor" => IoScope::Supervisor,
                other => {
                    return Err(Error::config(format!(
                        "unknown io fault scope {other:?} (expected children|supervisor)"
                    )))
                }
            };
            plan.io.push(IoFaultSpec {
                site: s.req_str("site")?.to_string(),
                kind,
                count: s.get("count").and_then(Value::as_u64).unwrap_or(1),
                scope,
            });
        }
        for h in section("host_loss") {
            plan.host_loss.push(HostLossSpec {
                at_poll: h.req_u64("at_poll")?,
                host: h.req_u64("host")? as usize,
            });
        }
        Ok(plan)
    }
}

/// Overwrite a complete middle record line of a JSON-lines checkpoint
/// with same-length garbage. Returns the damaged byte count, or
/// `None` if the file does not yet hold two complete non-header lines
/// (the caller keeps the spec pending). In-place same-length
/// overwrites are safe against a child still appending with
/// `O_APPEND`.
pub fn corrupt_middle_record(path: &Path) -> std::io::Result<Option<u64>> {
    use std::io::{Seek, SeekFrom, Write};
    let data = std::fs::read(path)?;
    let mut lines = Vec::new();
    let mut start = 0usize;
    for (i, &b) in data.iter().enumerate() {
        if b == b'\n' {
            lines.push((start, i));
            start = i + 1;
        }
    }
    let header = lines
        .first()
        .is_some_and(|&(s, e)| data[s..e].starts_with(b"{\"header\""));
    let records: &[(usize, usize)] = if header { &lines[1..] } else { &lines };
    if records.len() < 2 {
        return Ok(None);
    }
    // middle-most, and with >= 2 records never the last line
    let (s, e) = records[(records.len() - 1) / 2];
    if e <= s {
        return Ok(None);
    }
    let mut f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.seek(SeekFrom::Start(s as u64))?;
    f.write_all(&vec![b'x'; e - s])?;
    f.flush()?;
    Ok(Some((e - s) as u64))
}

/// Truncate `bytes` off the end of a checkpoint (a torn tail).
/// Returns the bytes removed, or `None` if the file is still empty.
pub fn truncate_tail(path: &Path, bytes: u64) -> std::io::Result<Option<u64>> {
    let len = std::fs::metadata(path)?.len();
    if len == 0 {
        return Ok(None);
    }
    let cut = bytes.max(1).min(len);
    let f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.set_len(len - cut)?;
    Ok(Some(cut))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("memfine-chaos-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn seeded_plan_is_deterministic_in_seed_and_dir() {
        let a = FaultPlan::from_seed(7, Path::new("campaign-a"));
        let b = FaultPlan::from_seed(7, Path::new("campaign-a"));
        assert_eq!(a, b);
        assert_ne!(a, FaultPlan::from_seed(8, Path::new("campaign-a")));
        assert_ne!(a, FaultPlan::from_seed(7, Path::new("campaign-b")));
        // fixed drill shape: kill storm + middle corruption + child ENOSPC
        assert_eq!(a.kills.len(), 2);
        assert!(a.kills.iter().all(|k| k.shard.is_none()));
        assert!(a.kills[0].at_poll < a.kills[1].at_poll);
        assert_eq!(a.corrupt.len(), 1);
        assert_eq!(a.corrupt[0].mode, CorruptMode::MiddleRecord);
        assert_eq!(a.io.len(), 1);
        assert_eq!(a.io[0].scope, IoScope::Children);
        assert_eq!(
            a.child_fault_env().as_deref(),
            Some("checkpoint:enospc:2"),
            "two charges: the ladder retries a record write once in place"
        );
        assert!(!a.is_empty());
    }

    #[test]
    fn plan_json_round_trips() {
        let plan = FaultPlan {
            seed: 3,
            kills: vec![
                KillSpec { at_poll: 2, shard: None },
                KillSpec { at_poll: 6, shard: Some(1) },
            ],
            corrupt: vec![
                CorruptSpec { at_poll: 4, shard: 0, mode: CorruptMode::MiddleRecord },
                CorruptSpec {
                    at_poll: 9,
                    shard: 2,
                    mode: CorruptMode::TruncateTail { bytes: 17 },
                },
            ],
            slow: vec![SlowSpec { shard: 1, delay_ms: 50 }],
            io: vec![IoFaultSpec {
                site: "trace-store".to_string(),
                kind: FaultKind::Eio,
                count: 2,
                scope: IoScope::Supervisor,
            }],
            host_loss: vec![HostLossSpec { at_poll: 3, host: 1 }],
        };
        let round = FaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(round, plan);
        // every section optional
        let empty = FaultPlan::from_json(&crate::json::parse("{}").unwrap()).unwrap();
        assert!(empty.is_empty());
        assert_eq!(empty, FaultPlan::default());
        // unknown tags are loud config errors
        for bad in [
            r#"{"corrupt": [{"at_poll": 1, "shard": 0, "mode": "bitflip"}]}"#,
            r#"{"io": [{"site": "checkpoint", "kind": "enoent"}]}"#,
            r#"{"io": [{"site": "checkpoint", "kind": "eio", "scope": "host"}]}"#,
        ] {
            assert!(FaultPlan::from_json(&crate::json::parse(bad).unwrap()).is_err());
        }
    }

    #[test]
    fn kill_one_matches_the_legacy_drill() {
        let plan = FaultPlan::kill_one();
        assert_eq!(
            plan.kills,
            vec![KillSpec { at_poll: 0, shard: None }]
        );
        assert!(plan.corrupt.is_empty() && plan.io.is_empty() && plan.slow.is_empty());
        assert!(plan.child_fault_env().is_none());
    }

    #[test]
    fn corrupt_middle_record_spares_header_and_tail() {
        let path = tmp("corrupt.jsonl");
        std::fs::write(
            &path,
            b"{\"header\":{\"p\":1}}\n{\"hash\":\"a\",\"result\":1}\n{\"hash\":\"b\",\"result\":2}\n{\"hash\":\"c\",\"result\":3}\n",
        )
        .unwrap();
        let before = std::fs::read(&path).unwrap();
        let damaged = corrupt_middle_record(&path).unwrap().unwrap();
        let after = std::fs::read(&path).unwrap();
        assert_eq!(after.len(), before.len(), "in-place, same length");
        let lines: Vec<&[u8]> = after.split(|&b| b == b'\n').collect();
        assert!(lines[0].starts_with(b"{\"header\""), "header intact");
        assert_eq!(lines[3], &before[before.len() - lines[3].len() - 1..before.len() - 1],
            "last record intact");
        assert!(lines[2].iter().all(|&b| b == b'x'), "middle record damaged");
        assert_eq!(damaged as usize, lines[2].len());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupt_middle_record_waits_for_enough_content() {
        let path = tmp("pending.jsonl");
        std::fs::write(&path, b"").unwrap();
        assert_eq!(corrupt_middle_record(&path).unwrap(), None);
        std::fs::write(&path, b"{\"header\":{}}\n{\"hash\":\"a\"}\n").unwrap();
        assert_eq!(
            corrupt_middle_record(&path).unwrap(),
            None,
            "one record is not enough: the last line is never damaged"
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncate_tail_tears_the_file() {
        let path = tmp("truncate.jsonl");
        std::fs::write(&path, b"").unwrap();
        assert_eq!(truncate_tail(&path, 5).unwrap(), None);
        std::fs::write(&path, b"{\"hash\":\"a\"}\n{\"hash\":\"b\"}\n").unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(truncate_tail(&path, 5).unwrap(), Some(5));
        assert_eq!(std::fs::metadata(&path).unwrap().len(), len - 5);
        // over-long cuts stop at empty, never error
        assert_eq!(truncate_tail(&path, 10_000).unwrap(), Some(len - 5));
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        std::fs::remove_file(&path).unwrap();
    }
}
