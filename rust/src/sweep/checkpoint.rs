//! Resumable sweeps: a JSON-lines checkpoint of completed scenarios,
//! keyed by content hash, mergeable across shards and hosts.
//!
//! Every scenario is identified by [`scenario_hash`] — FNV-1a 64 over
//! the canonical compact JSON of its fully-resolved
//! [`RunConfig`](crate::config::RunConfig) plus the trace provenance
//! ([`TraceProvenance`]: router-sampler tag and, for post-v1
//! generators, the RNG version). The hash therefore captures *what
//! will be simulated* (model, parallelism, method, seed, iterations,
//! memory envelope, sampler/RNG provenance) and deliberately excludes
//! *how it is executed* (worker count, shard split, grid position):
//! two hosts running different shards of the same grid, or re-runs of
//! a reordered/extended grid, agree on every hash. Within one trace
//! cell the scenarios differ **only** in method, so the per-scenario
//! loops of resume, audit and planning hash through a [`CellHasher`]:
//! the cell-invariant JSON (model, parallel, seed, envelope,
//! provenance) is serialised and FNV-folded once per cell and only
//! the method value is re-hashed per scenario — same hashes, a
//! fraction of the serialisation work.
//!
//! The file format is an optional provenance header followed by one
//! line per completed scenario:
//!
//! ```text
//! {"header":{"rng_algorithm":"...","rng_version":1,"router":"split"}}
//! {"hash":"94fd0a31c7e02b44","result":{...ScenarioResult row...}}
//! ```
//!
//! appended and flushed as each scenario finishes, so a killed sweep
//! loses at most the in-flight cells. The header records what the
//! rows were drawn under (sampler + RNG version) — `memfine
//! checkpoint audit` uses it to pick the right hash universe without
//! being told, and pre-header files simply have no header line (their
//! rows still resume fine: provenance is baked into every row's
//! hash). Loading tolerates a torn final line (the kill-mid-write
//! case) by skipping lines that fail to parse and reporting the
//! count; merging is file concatenation or passing several
//! `--checkpoint` paths — duplicate hashes collapse (results are
//! deterministic, so duplicates are identical).
//!
//! On resume the stored row's `index` is re-derived from the *current*
//! grid (hashes are position-independent), which keeps the final
//! artifact byte-identical to an uninterrupted run of that grid — the
//! kill-and-resume integration test pins this.
//!
//! Rows are engine-agnostic: the fused cell evaluator
//! ([`crate::sim::evaluate_cell`], the default) and the per-method
//! path (`--unfused`) emit byte-identical
//! [`ScenarioResult`](crate::sweep::report::ScenarioResult) lines, so
//! checkpoints written under either engine resume under the other —
//! the CLI tests and the CI smoke cross-merge them deliberately.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::config::{Method, RunConfig};
use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::sweep::report::ScenarioResult;
use crate::trace::provenance::TraceProvenance;
use crate::util::{fnv1a_64, fnv1a_64_update, FNV1A_OFFSET};

/// The canonical hash document of one scenario: the provenance fields
/// (version-1 serialises exactly the historical `{"router": tag}`, so
/// every pre-provenance hash is preserved) plus the resolved run
/// envelope.
fn hash_doc(run: &RunConfig, prov: &TraceProvenance) -> Value {
    let mut fields = prov.hash_fields();
    fields.push(("run", run.to_json()));
    json::obj(fields)
}

/// Content hash of one scenario: FNV-1a 64 (16 hex chars) over the
/// canonical run JSON plus the trace provenance. The sampler (and any
/// future RNG version bump) changes the drawn trace — same
/// distribution, different bits — so it is part of the identity: a
/// checkpoint written under one provenance never silently satisfies a
/// sweep run under another.
pub fn scenario_hash(run: &RunConfig, prov: &TraceProvenance) -> String {
    format!(
        "{:016x}",
        fnv1a_64(hash_doc(run, prov).to_string_compact().as_bytes())
    )
}

/// Per-trace-cell scenario hasher. A cell's scenarios differ only in
/// `method`, yet [`scenario_hash`] re-serialises the entire canonical
/// envelope per call — which the resume/audit/plan loops used to pay
/// per *scenario*. `CellHasher` serialises the envelope once, splits
/// it around the method value, pre-folds the FNV state over the
/// prefix, and per scenario re-hashes only the method JSON plus the
/// cached suffix. Bit-identical to [`scenario_hash`] by construction
/// (FNV-1a streams over concatenated bytes) and pinned by tests and a
/// debug assertion.
pub struct CellHasher {
    /// FNV state after folding everything up to (and including) the
    /// `"method":` key of the canonical document.
    prefix_hash: u64,
    /// Canonical bytes after the method value.
    suffix: String,
}

impl CellHasher {
    /// Build from any scenario of the cell (its method is irrelevant —
    /// only the cell-invariant fields are retained).
    pub fn new(run: &RunConfig, prov: &TraceProvenance) -> Self {
        let doc = hash_doc(run, prov).to_string_compact();
        let method_json = run.method.to_json().to_string_compact();
        let marker = format!("\"method\":{method_json}");
        // RunConfig's canonical JSON has exactly one "method" key and
        // no free-form string values that could fake one.
        let pos = doc
            .find(&marker)
            .expect("canonical run JSON contains its method field");
        let split = pos + "\"method\":".len();
        let hasher = CellHasher {
            prefix_hash: fnv1a_64_update(FNV1A_OFFSET, doc[..split].as_bytes()),
            suffix: doc[split + method_json.len()..].to_string(),
        };
        debug_assert_eq!(hasher.hash(&run.method), scenario_hash(run, prov));
        hasher
    }

    /// The cell scenario with this method — equals
    /// `scenario_hash(run_with(method), prov)`.
    pub fn hash(&self, method: &Method) -> String {
        let h = fnv1a_64_update(
            self.prefix_hash,
            method.to_json().to_string_compact().as_bytes(),
        );
        format!("{:016x}", fnv1a_64_update(h, self.suffix.as_bytes()))
    }
}

/// Completed scenarios loaded from checkpoint files, keyed by hash.
#[derive(Debug, Default)]
pub struct CheckpointSet {
    map: BTreeMap<String, ScenarioResult>,
    /// Lines that failed to parse (torn tail of a killed run, stray
    /// garbage) — skipped, surfaced so the CLI can report them.
    pub skipped_lines: usize,
    /// Files that existed and were read.
    pub loaded_files: usize,
    /// Non-blank lines seen across all files (headers included).
    pub total_lines: usize,
    /// Parseable records that duplicated an already-loaded hash
    /// (identical by the determinism contract; later files win).
    pub duplicate_records: usize,
    /// Header lines seen across all files.
    pub header_lines: usize,
    /// The recorded trace provenance, when every header agrees.
    /// `None` with `header_lines == 0` means legacy (pre-header)
    /// files; `None` with headers seen means the files disagree —
    /// the caller must say which universe it wants.
    pub header_provenance: Option<TraceProvenance>,
    /// Headers were seen but disagreed (locks `header_provenance`).
    header_conflict: bool,
}

/// One parsed checkpoint line.
enum CheckpointLine {
    Header(TraceProvenance),
    Record(String, ScenarioResult),
}

impl CheckpointSet {
    pub fn empty() -> Self {
        CheckpointSet::default()
    }

    /// Load and merge checkpoint files. Missing files are fine (a
    /// shard that never started); unreadable lines are skipped and
    /// counted. Later files win on duplicate hashes — by the
    /// determinism contract duplicates carry identical results, so
    /// the choice is immaterial.
    pub fn load(paths: &[PathBuf]) -> Result<Self> {
        let mut set = CheckpointSet::empty();
        for path in paths {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => {
                    return Err(Error::Io(std::io::Error::new(
                        e.kind(),
                        format!("checkpoint {}: {e}", path.display()),
                    )))
                }
            };
            set.loaded_files += 1;
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                set.total_lines += 1;
                match Self::parse_line(line) {
                    Ok(CheckpointLine::Header(prov)) => set.note_header(prov),
                    Ok(CheckpointLine::Record(hash, result)) => {
                        if set.map.insert(hash, result).is_some() {
                            set.duplicate_records += 1;
                        }
                    }
                    Err(_) => set.skipped_lines += 1,
                }
            }
        }
        Ok(set)
    }

    fn parse_line(line: &str) -> Result<CheckpointLine> {
        let v = json::parse(line)?;
        if let Some(h) = v.get("header") {
            return Ok(CheckpointLine::Header(TraceProvenance::from_json(h)?));
        }
        let hash = v.req_str("hash")?.to_string();
        let result = ScenarioResult::from_json(
            v.get("result")
                .ok_or_else(|| Error::config("checkpoint line missing result"))?,
        )?;
        Ok(CheckpointLine::Record(hash, result))
    }

    /// Read just the recorded provenance headers of the given files —
    /// the first line of each that exists — without loading any rows.
    /// `Some` when at least one header was found and all of them
    /// agree; `None` for legacy headerless files, unreadable first
    /// lines, or disagreeing headers. This is how `memfine sweep
    /// --resume` (and `checkpoint audit`) adopt a checkpoint's
    /// recorded sampler instead of silently re-hashing a pre-flip
    /// file under the new default.
    pub fn peek_provenance(paths: &[PathBuf]) -> Option<TraceProvenance> {
        use std::io::{BufRead, BufReader};
        let mut recorded: Option<TraceProvenance> = None;
        for path in paths {
            let Ok(f) = std::fs::File::open(path) else {
                continue; // missing shard file: fine, like load()
            };
            let mut first = String::new();
            if BufReader::new(f).read_line(&mut first).is_err() {
                continue;
            }
            let Ok(CheckpointLine::Header(prov)) = Self::parse_line(first.trim_end())
            else {
                // headerless (legacy) or torn first line: no recorded
                // provenance for this file — the set has none overall
                return None;
            };
            match &recorded {
                None => recorded = Some(prov),
                Some(prev) if *prev == prov => {}
                Some(_) => return None,
            }
        }
        recorded
    }

    fn note_header(&mut self, prov: TraceProvenance) {
        self.header_lines += 1;
        if self.header_conflict {
            return;
        }
        match &self.header_provenance {
            None if self.header_lines == 1 => self.header_provenance = Some(prov),
            Some(prev) if *prev == prov => {}
            _ => {
                self.header_provenance = None;
                self.header_conflict = true;
            }
        }
    }

    pub fn get(&self, hash: &str) -> Option<&ScenarioResult> {
        self.map.get(hash)
    }

    pub fn contains(&self, hash: &str) -> bool {
        self.map.contains_key(hash)
    }

    /// Records in canonical (ascending hash) order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &ScenarioResult)> {
        self.map.iter().map(|(h, r)| (h.as_str(), r))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// What [`compact`] read and wrote.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CompactStats {
    /// Input files read (all must exist — compaction of a missing
    /// checkpoint is an operator error, unlike resume's tolerance).
    pub files_in: usize,
    /// Non-blank input lines seen.
    pub lines_in: usize,
    /// Unparseable lines dropped (torn tails, stray garbage).
    pub dropped_lines: usize,
    /// Parseable records dropped as duplicates of an earlier hash
    /// (identical by the determinism contract).
    pub duplicate_records: usize,
    /// Records in the compacted output.
    pub records_out: usize,
}

/// Rewrite one or more checkpoint files as a single canonical file:
/// duplicate hashes collapse, torn/garbage lines are dropped, and
/// records are emitted in ascending hash order — so compacting the
/// same logical content always yields the same bytes, and re-running
/// compact on its own output is a fixpoint. The output is written to
/// `<output>.tmp` and renamed into place, so a kill mid-compaction
/// never corrupts an existing checkpoint (in-place compaction,
/// `output` ∈ `inputs`, is safe for the same reason: inputs are fully
/// read before the write starts).
pub fn compact(inputs: &[PathBuf], output: &Path) -> Result<CompactStats> {
    for path in inputs {
        if !path.exists() {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("compact checkpoint {}: no such file", path.display()),
            )));
        }
    }
    let set = CheckpointSet::load(inputs)?;
    write_compacted(&set, output)
}

/// Write an already-loaded checkpoint set as a canonical compacted
/// file (the tail of [`compact`], split out so callers that hold a
/// [`CheckpointSet`] — the orchestrator's merge step audits one —
/// can compact without re-reading every shard file from disk).
pub fn write_compacted(set: &CheckpointSet, output: &Path) -> Result<CompactStats> {
    let mut tmp_name = output.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    // a compaction killed between create and rename leaves `<output>.tmp`
    // behind; a stale tmp (possibly from a *different* set) must not
    // survive into — or collide with — this run, so drop it first and
    // clean up again on every error path below
    if tmp.exists() {
        std::fs::remove_file(&tmp).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("remove stale {}: {e}", tmp.display()),
            ))
        })?;
    }
    let write = (|| -> Result<()> {
        // the compacted file re-records the inputs' provenance header
        // when they agree on one (legacy/conflicting inputs compact to
        // a headerless file rather than inventing a provenance)
        let mut w = CheckpointWriter::create(&tmp, set.header_provenance.as_ref())?;
        for (hash, result) in set.iter() {
            w.record(hash, result)?;
        }
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Err(e) = std::fs::rename(&tmp, output) {
        let _ = std::fs::remove_file(&tmp);
        return Err(Error::Io(std::io::Error::new(
            e.kind(),
            format!("rename {} -> {}: {e}", tmp.display(), output.display()),
        )));
    }
    Ok(CompactStats {
        files_in: set.loaded_files,
        lines_in: set.total_lines,
        dropped_lines: set.skipped_lines,
        duplicate_records: set.duplicate_records,
        records_out: set.len(),
    })
}

/// Result of checking a checkpoint set against the grid it claims to
/// cover (see [`audit_coverage`]).
#[derive(Clone, Debug)]
pub struct CoverageAudit {
    /// Scenarios the grid plans.
    pub planned: usize,
    /// Planned scenarios present in the checkpoint set.
    pub present: usize,
    /// Planned scenarios absent from the set: (grid index, hash),
    /// index-ascending.
    pub missing: Vec<(usize, String)>,
    /// Records in the set that belong to no planned scenario (another
    /// grid's rows, or rows written under the other router sampler).
    pub extra: usize,
}

impl CoverageAudit {
    /// Every planned scenario is present.
    pub fn complete(&self) -> bool {
        self.missing.is_empty()
    }
}

/// Audit a checkpoint set against a sweep grid: expand the grid,
/// derive every scenario's content hash under the given trace
/// provenance (one [`CellHasher`] per trace cell — the envelope is
/// serialised once per cell, not once per scenario), and report which
/// planned scenarios are present, missing, or foreign to the grid.
/// This is how the orchestrator proves the merged artifact covers
/// every planned scenario before it publishes a report (and how
/// `memfine checkpoint audit` exposes the same check standalone).
pub fn audit_coverage(
    cfg: &crate::config::SweepConfig,
    prov: &TraceProvenance,
    set: &CheckpointSet,
) -> Result<CoverageAudit> {
    Ok(audit_planned(&planned_hashes(cfg, prov)?, set))
}

/// Every scenario of the grid as (grid index, content hash),
/// index-ascending — the coverage contract [`audit_coverage`] and the
/// orchestrator's launch plan both audit against, hashed per cell.
pub fn planned_hashes(
    cfg: &crate::config::SweepConfig,
    prov: &TraceProvenance,
) -> Result<Vec<(usize, String)>> {
    let cells = crate::sweep::grid::expand_cells(cfg)?;
    let mut planned: Vec<(usize, String)> = Vec::with_capacity(cfg.scenario_count());
    for cell in &cells {
        let hasher = CellHasher::new(&cell.scenarios[0].run, prov);
        for sc in &cell.scenarios {
            planned.push((sc.index, hasher.hash(&sc.method)));
        }
    }
    planned.sort_unstable_by_key(|&(index, _)| index);
    Ok(planned)
}

/// [`audit_coverage`] against an already-derived planned hash set —
/// the orchestrator plans every scenario hash once up front
/// ([`crate::orchestrator::plan::LaunchPlan::planned`]) and audits
/// against it without re-expanding and re-hashing the grid.
pub fn audit_planned(planned: &[(usize, String)], set: &CheckpointSet) -> CoverageAudit {
    let mut present = 0usize;
    let mut missing = Vec::new();
    for (index, hash) in planned {
        if set.contains(hash) {
            present += 1;
        } else {
            missing.push((*index, hash.clone()));
        }
    }
    CoverageAudit {
        planned: planned.len(),
        present,
        missing,
        extra: set.len().saturating_sub(present),
    }
}

/// Set once the process has warned about a checkpoint-header
/// provenance mismatch — large launches resume dozens of checkpoint
/// sets (every shard child, plus the merge catch-up), and each used to
/// print its own copy of the same warning, drowning stderr.
static PROVENANCE_WARNED: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Warn that resumed checkpoint files record a different trace
/// provenance than this run executes under — **at most once per
/// process**, with the shard context when the caller is a shard child.
/// The mismatch is safe (provenance is baked into every row hash, so
/// foreign rows simply don't resume) but almost always means the whole
/// grid will re-run, which the operator should know about exactly once.
pub fn warn_provenance_mismatch(
    recorded: &TraceProvenance,
    using: &TraceProvenance,
    shard: Option<&crate::config::ShardSpec>,
) {
    use std::sync::atomic::Ordering;
    if PROVENANCE_WARNED.swap(true, Ordering::Relaxed) {
        return;
    }
    let ctx = match shard {
        Some(s) => format!("shard {}/{}: ", s.index, s.count),
        None => String::new(),
    };
    crate::logging::warn(
        "sweep::checkpoint",
        format!(
            "{ctx}checkpoint records router '{}' rng v{} but this run uses router '{}' \
             rng v{}; recorded rows will not resume under this run's hashes (pass \
             --router/--rng to match, or omit them to adopt the recorded provenance)",
            recorded.sampler.tag(),
            recorded.rng_version,
            using.sampler.tag(),
            using.rng_version,
        ),
    );
}

/// Appends one line per completed scenario, flushed immediately so a
/// kill loses at most in-flight work. `disabled()` is the no-op used
/// when no `--checkpoint` path is configured.
#[derive(Debug)]
pub struct CheckpointWriter {
    out: Option<std::fs::File>,
    records_written: u64,
}

impl CheckpointWriter {
    pub fn disabled() -> Self {
        CheckpointWriter { out: None, records_written: 0 }
    }

    /// Whether this writer actually appends (a `--checkpoint` path was
    /// configured).
    pub fn enabled(&self) -> bool {
        self.out.is_some()
    }

    /// Scenario records written by this writer (header excluded).
    pub fn records_written(&self) -> u64 {
        self.records_written
    }

    /// Start a fresh checkpoint (truncates an existing file — the
    /// non-`--resume` path), recording the trace provenance as the
    /// header line when given.
    pub fn create(path: &Path, header: Option<&TraceProvenance>) -> Result<Self> {
        let f = std::fs::File::create(path).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("create checkpoint {}: {e}", path.display()),
            ))
        })?;
        let mut w = CheckpointWriter { out: Some(f), records_written: 0 };
        if let Some(prov) = header {
            w.write_header(prov)?;
        }
        Ok(w)
    }

    /// Append to an existing checkpoint (the `--resume` path; the file
    /// may not exist yet). A brand-new (empty) file gets the
    /// provenance header first; an existing file keeps whatever header
    /// era it was started in. If a previous run died mid-write the
    /// file ends in a torn fragment without a newline — terminate it
    /// so the next record starts on its own line (the fragment stays
    /// unparseable and is skipped on load; its scenario simply
    /// re-runs).
    pub fn append(path: &Path, header: Option<&TraceProvenance>) -> Result<Self> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = std::fs::File::options()
            .read(true)
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| {
                Error::Io(std::io::Error::new(
                    e.kind(),
                    format!("append checkpoint {}: {e}", path.display()),
                ))
            })?;
        let len = f.metadata().map_err(Error::Io)?.len();
        if len > 0 {
            f.seek(SeekFrom::End(-1)).map_err(Error::Io)?;
            let mut last = [0u8; 1];
            f.read_exact(&mut last).map_err(Error::Io)?;
            if last[0] != b'\n' {
                // append mode: the write lands at EOF regardless of
                // the read cursor
                f.write_all(b"\n").map_err(Error::Io)?;
            }
        }
        let mut w = CheckpointWriter { out: Some(f), records_written: 0 };
        if len == 0 {
            if let Some(prov) = header {
                w.write_header(prov)?;
            }
        }
        Ok(w)
    }

    /// Write the provenance header line (first line of a fresh file).
    fn write_header(&mut self, prov: &TraceProvenance) -> Result<()> {
        let Some(f) = self.out.as_mut() else {
            return Ok(());
        };
        let line = json::obj(vec![("header", prov.to_json())]).to_string_compact();
        f.write_all(line.as_bytes())
            .and_then(|_| f.write_all(b"\n"))
            .and_then(|_| f.flush())
            .map_err(Error::Io)
    }

    /// Record one completed scenario. One compact-JSON line, written
    /// and flushed atomically enough for the torn-line loader: a kill
    /// mid-write corrupts at most the final line. This is the
    /// streaming write path chaos drills inject IO faults into
    /// ([`crate::faultfs`]); the sweep engine runs it through a
    /// degradation ladder, so a failed record costs re-execution on
    /// resume, never the run.
    pub fn record(&mut self, hash: &str, result: &ScenarioResult) -> Result<()> {
        let Some(f) = self.out.as_mut() else {
            return Ok(());
        };
        crate::faultfs::check(crate::faultfs::SITE_CHECKPOINT).map_err(Error::Io)?;
        let line = json::obj(vec![
            ("hash", json::s(hash.to_string())),
            ("result", result.to_json()),
        ])
        .to_string_compact();
        f.write_all(line.as_bytes())
            .and_then(|_| f.write_all(b"\n"))
            .and_then(|_| f.flush())
            .map_err(Error::Io)?;
        self.records_written += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_run, Method};
    use crate::trace::provenance::RouterSampler;

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("memfine-ckpt-test-{}-{name}", std::process::id()));
        p
    }

    /// The pre-flip provenance most of these fixtures were written
    /// under (sequential sampler, RNG v1).
    fn seq() -> TraceProvenance {
        TraceProvenance::legacy_sequential()
    }

    fn sample_result(index: usize, seed: u64) -> ScenarioResult {
        ScenarioResult {
            index,
            model: "i".into(),
            method: Method::FixedChunk(8).name(),
            seed,
            iterations: 10,
            trained: true,
            oom_iterations: 0,
            avg_tgs: 1234.5678901234,
            peak_act_bytes: 9_876_543_210,
            peak_total_bytes: 19_876_543_210,
            static_bytes: 5_000_000_000,
        }
    }

    #[test]
    fn hash_is_stable_and_content_sensitive() {
        let run = paper_run(model_i(), Method::FullRecompute);
        let h = scenario_hash(&run, &seq());
        assert_eq!(h.len(), 16);
        assert_eq!(h, scenario_hash(&run, &seq()));
        // every identity-bearing field perturbs the hash
        let mut seed_run = run.clone();
        seed_run.seed += 1;
        assert_ne!(h, scenario_hash(&seed_run, &seq()));
        let mut iters = run.clone();
        iters.iterations += 1;
        assert_ne!(h, scenario_hash(&iters, &seq()));
        let mut method = run.clone();
        method.method = Method::FixedChunk(8);
        assert_ne!(h, scenario_hash(&method, &seq()));
        let mut mem = run.clone();
        mem.gpu_mem_bytes /= 2;
        assert_ne!(h, scenario_hash(&mem, &seq()));
        // the provenance is part of the identity: sampler tag and any
        // post-v1 RNG version both perturb the hash
        let split = TraceProvenance::current(RouterSampler::Split);
        assert_ne!(h, scenario_hash(&run, &split));
        let v2 = TraceProvenance { sampler: RouterSampler::Sequential, rng_version: 2 };
        assert_ne!(h, scenario_hash(&run, &v2));
    }

    #[test]
    fn cell_hasher_matches_scenario_hash() {
        // The cell-level hasher must reproduce scenario_hash exactly
        // for every method kind, under every provenance — including a
        // future RNG version whose hash doc gains a field.
        let methods = [
            Method::FullRecompute,
            Method::FixedChunk(4),
            Method::Mact(vec![1, 2, 4, 8]),
        ];
        for prov in [
            seq(),
            TraceProvenance::current(RouterSampler::Split),
            TraceProvenance { sampler: RouterSampler::Split, rng_version: 2 },
        ] {
            // built from one method, queried for all of them
            let base = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
            let hasher = CellHasher::new(&base, &prov);
            for method in &methods {
                let mut run = base.clone();
                run.method = method.clone();
                assert_eq!(
                    hasher.hash(method),
                    scenario_hash(&run, &prov),
                    "{method:?} under {prov:?}"
                );
            }
        }
    }

    #[test]
    fn writer_then_loader_roundtrip() {
        let path = tmp_path("roundtrip");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        let hash = scenario_hash(&run, &seq());
        let result = sample_result(3, 7);
        {
            let mut w = CheckpointWriter::create(&path, Some(&seq())).unwrap();
            w.record(&hash, &result).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped_lines, 0);
        // the file recorded its provenance header
        assert_eq!(set.header_lines, 1);
        assert_eq!(set.header_provenance, Some(seq()));
        let back = set.get(&hash).unwrap();
        assert_eq!(back, &result);
        assert_eq!(back.avg_tgs.to_bits(), result.avg_tgs.to_bits());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_headerless_files_still_load() {
        // A pre-provenance checkpoint (raw record lines, no header)
        // must load exactly as before: rows resume by hash, and the
        // absence of a header is observable (auditors fall back to an
        // explicit sampler choice).
        let path = tmp_path("legacy");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        let hash = scenario_hash(&run, &seq());
        let line = json::obj(vec![
            ("hash", json::s(hash.clone())),
            ("result", sample_result(0, 7).to_json()),
        ])
        .to_string_compact();
        std::fs::write(&path, format!("{line}\n")).unwrap();
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.header_lines, 0);
        assert!(set.header_provenance.is_none());
        assert!(set.get(&hash).is_some());
        // appending via the writer does NOT inject a header mid-file
        {
            let mut w = CheckpointWriter::append(&path, Some(&seq())).unwrap();
            let run2 = paper_run(model_i(), Method::FullRecompute);
            w.record(&scenario_hash(&run2, &seq()), &sample_result(1, 7)).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.header_lines, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn peek_provenance_reads_headers_cheaply() {
        let a = tmp_path("peek-a");
        let b = tmp_path("peek-b");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        {
            let mut w = CheckpointWriter::create(&a, Some(&seq())).unwrap();
            w.record(&scenario_hash(&run, &seq()), &sample_result(0, 7)).unwrap();
        }
        // agreeing headers (missing files are skipped like load())
        let missing = tmp_path("peek-missing");
        assert_eq!(
            CheckpointSet::peek_provenance(&[a.clone(), missing]),
            Some(seq())
        );
        // a headerless legacy file in the set: no trusted provenance
        let line = json::obj(vec![
            ("hash", json::s(scenario_hash(&run, &seq()))),
            ("result", sample_result(0, 7).to_json()),
        ])
        .to_string_compact();
        std::fs::write(&b, format!("{line}\n")).unwrap();
        assert_eq!(CheckpointSet::peek_provenance(&[a.clone(), b.clone()]), None);
        // disagreeing headers: no trusted provenance either
        let split = TraceProvenance::current(RouterSampler::Split);
        {
            let _w = CheckpointWriter::create(&b, Some(&split)).unwrap();
        }
        assert_eq!(CheckpointSet::peek_provenance(&[a.clone(), b.clone()]), None);
        assert_eq!(
            CheckpointSet::peek_provenance(std::slice::from_ref(&b)),
            Some(split)
        );
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn conflicting_headers_yield_no_provenance() {
        let a = tmp_path("hdr-a");
        let b = tmp_path("hdr-b");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        {
            let mut w = CheckpointWriter::create(&a, Some(&seq())).unwrap();
            w.record(&scenario_hash(&run, &seq()), &sample_result(0, 7)).unwrap();
        }
        {
            let split = TraceProvenance::current(RouterSampler::Split);
            let mut w = CheckpointWriter::create(&b, Some(&split)).unwrap();
            w.record(&scenario_hash(&run, &split), &sample_result(0, 7)).unwrap();
        }
        // each alone reports its own provenance
        let only_a = CheckpointSet::load(std::slice::from_ref(&a)).unwrap();
        assert_eq!(only_a.header_provenance, Some(seq()));
        // together they disagree: no provenance, both headers counted
        let both = CheckpointSet::load(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(both.header_lines, 2);
        assert!(both.header_provenance.is_none());
        assert_eq!(both.len(), 2);
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn loader_skips_torn_final_line() {
        let path = tmp_path("torn");
        let run = paper_run(model_i(), Method::FixedChunk(8));
        let hash = scenario_hash(&run, &seq());
        {
            let mut w = CheckpointWriter::create(&path, Some(&seq())).unwrap();
            w.record(&hash, &sample_result(0, 7)).unwrap();
        }
        // simulate a kill mid-write: half a second line, no newline
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&path).unwrap();
            f.write_all(b"{\"hash\":\"deadbeef\",\"resu").unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped_lines, 1);
        assert!(set.get(&hash).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loader_skips_corrupted_middle_record() {
        // Mid-file corruption (a chaos drill's corrupt_middle_record,
        // bit rot, a partial overwrite) must degrade exactly like a
        // torn tail: the damaged line is skipped and counted, every
        // intact neighbour still loads, and the lost scenario is
        // simply re-executed by resume/merge catch-up.
        let path = tmp_path("corrupt-middle");
        let runs = [
            paper_run(model_i(), Method::FullRecompute),
            paper_run(model_i(), Method::FixedChunk(8)),
            paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8])),
        ];
        let hashes: Vec<String> =
            runs.iter().map(|r| scenario_hash(r, &seq())).collect();
        {
            let mut w = CheckpointWriter::create(&path, Some(&seq())).unwrap();
            for (i, h) in hashes.iter().enumerate() {
                w.record(h, &sample_result(i, 7)).unwrap();
            }
        }
        let healthy = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(healthy.len(), 3);
        // damage the middle record in place (same length, so the tail
        // records keep their byte offsets — exactly what the chaos
        // helper does)
        let n = crate::orchestrator::chaos::corrupt_middle_record(&path)
            .unwrap()
            .expect("three records is enough to corrupt");
        assert!(n > 0);
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        assert_eq!(set.len(), 2, "both intact neighbours survive");
        assert_eq!(set.skipped_lines, 1, "the damage is counted, not fatal");
        assert_eq!(set.header_lines, 1, "the header is never the target");
        assert!(set.get(&hashes[0]).is_some());
        assert!(set.get(&hashes[1]).is_none(), "the middle record is the loss");
        assert!(set.get(&hashes[2]).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loader_merges_files_and_missing_files_are_fine() {
        let a = tmp_path("merge-a");
        let b = tmp_path("merge-b");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, &seq()), scenario_hash(&run2, &seq()));
        {
            let mut w = CheckpointWriter::create(&a, Some(&seq())).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::create(&b, Some(&seq())).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
            // duplicate of h1: collapses
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        let missing = tmp_path("never-written");
        let set =
            CheckpointSet::load(&[a.clone(), b.clone(), missing]).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.loaded_files, 2);
        assert!(set.get(&h1).is_some() && set.get(&h2).is_some());
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn append_terminates_torn_tail_before_writing() {
        let path = tmp_path("torn-append");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, &seq()), scenario_hash(&run2, &seq()));
        {
            let mut w = CheckpointWriter::create(&path, Some(&seq())).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&path).unwrap();
            f.write_all(b"{\"hash\":\"torn").unwrap();
        }
        {
            let mut w = CheckpointWriter::append(&path, Some(&seq())).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        // both complete records load; only the torn fragment is lost
        assert_eq!(set.len(), 2);
        assert_eq!(set.skipped_lines, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn create_truncates_append_preserves() {
        let path = tmp_path("trunc");
        let run = paper_run(model_i(), Method::FullRecompute);
        let hash = scenario_hash(&run, &seq());
        {
            let mut w = CheckpointWriter::create(&path, Some(&seq())).unwrap();
            w.record(&hash, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::append(&path, Some(&seq())).unwrap();
            let run2 = paper_run(model_i(), Method::FixedChunk(8));
            w.record(&scenario_hash(&run2, &seq()), &sample_result(1, 7)).unwrap();
        }
        assert_eq!(CheckpointSet::load(std::slice::from_ref(&path)).unwrap().len(), 2);
        {
            let _w = CheckpointWriter::create(&path, Some(&seq())).unwrap();
        }
        assert!(CheckpointSet::load(std::slice::from_ref(&path)).unwrap().is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn disabled_writer_is_a_noop() {
        let mut w = CheckpointWriter::disabled();
        w.record("abc", &sample_result(0, 1)).unwrap();
    }

    #[test]
    fn compact_dedupes_drops_torn_tail_and_canonicalises() {
        let a = tmp_path("compact-a");
        let b = tmp_path("compact-b");
        let out = tmp_path("compact-out");
        let run1 = paper_run(model_i(), Method::FullRecompute);
        let run2 = paper_run(model_i(), Method::FixedChunk(8));
        let (h1, h2) = (scenario_hash(&run1, &seq()), scenario_hash(&run2, &seq()));
        {
            let mut w = CheckpointWriter::create(&a, Some(&seq())).unwrap();
            w.record(&h2, &sample_result(1, 7)).unwrap();
            w.record(&h1, &sample_result(0, 7)).unwrap();
            // duplicate of h1 within the same file
            w.record(&h1, &sample_result(0, 7)).unwrap();
        }
        {
            let mut w = CheckpointWriter::create(&b, Some(&seq())).unwrap();
            // cross-file duplicate of h2, then a torn tail
            w.record(&h2, &sample_result(1, 7)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::File::options().append(true).open(&b).unwrap();
            f.write_all(b"{\"hash\":\"dead").unwrap();
        }
        let stats = compact(&[a.clone(), b.clone()], &out).unwrap();
        assert_eq!(stats.files_in, 2);
        assert_eq!(stats.lines_in, 7); // 5 record/torn lines + 2 headers
        assert_eq!(stats.dropped_lines, 1);
        assert_eq!(stats.duplicate_records, 2);
        assert_eq!(stats.records_out, 2);
        // the compacted file loads clean
        let set = CheckpointSet::load(std::slice::from_ref(&out)).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.skipped_lines, 0);
        // the agreeing input headers were re-recorded in the output
        assert_eq!(set.header_lines, 1);
        assert_eq!(set.header_provenance, Some(seq()));
        // records come out hash-ascending
        let hashes: Vec<String> = set.iter().map(|(h, _)| h.to_string()).collect();
        let mut sorted = hashes.clone();
        sorted.sort();
        assert_eq!(hashes, sorted);
        // compaction is a fixpoint: recompacting its own output
        // (in-place) changes nothing
        let bytes = std::fs::read(&out).unwrap();
        let again = compact(&[out.clone()], &out).unwrap();
        assert_eq!(again.records_out, 2);
        assert_eq!(again.duplicate_records, 0);
        assert_eq!(again.dropped_lines, 0);
        assert_eq!(std::fs::read(&out).unwrap(), bytes);
        for p in [&a, &b, &out] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn compact_missing_input_is_an_error() {
        let missing = tmp_path("compact-missing");
        let out = tmp_path("compact-missing-out");
        assert!(compact(&[missing], &out).is_err());
    }

    #[test]
    fn compact_survives_a_stale_tmp_from_a_killed_run() {
        let a = tmp_path("compact-stale-in");
        let out = tmp_path("compact-stale-out");
        let mut tmp = out.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        let run = paper_run(model_i(), Method::FullRecompute);
        let h = scenario_hash(&run, &seq());
        {
            let mut w = CheckpointWriter::create(&a, Some(&seq())).unwrap();
            w.record(&h, &sample_result(0, 7)).unwrap();
        }
        // a compaction of some *other* set died between create and
        // rename, stranding garbage at `<output>.tmp` — the next
        // compact must neither fail on it nor let it leak into the
        // output
        std::fs::write(&tmp, b"{\"hash\":\"dead-stale-garbage\n").unwrap();
        let stats = compact(&[a.clone()], &out).unwrap();
        assert_eq!(stats.records_out, 1);
        assert!(!tmp.exists(), "stale tmp must be consumed by the rename");
        let set = CheckpointSet::load(std::slice::from_ref(&out)).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped_lines, 0);
        for p in [&a, &out] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn compact_cleans_its_tmp_when_rename_fails() {
        let a = tmp_path("compact-renamefail-in");
        let out = tmp_path("compact-renamefail-out");
        let mut tmp = out.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        let run = paper_run(model_i(), Method::FullRecompute);
        let h = scenario_hash(&run, &seq());
        {
            let mut w = CheckpointWriter::create(&a, Some(&seq())).unwrap();
            w.record(&h, &sample_result(0, 7)).unwrap();
        }
        // renaming a file onto a non-empty directory fails on every
        // platform we run on, forcing the rename error path
        std::fs::create_dir_all(out.join("occupied")).unwrap();
        assert!(compact(&[a.clone()], &out).is_err());
        assert!(!tmp.exists(), "failed compact must not leak its tmp");
        std::fs::remove_dir_all(&out).ok();
        std::fs::remove_file(&a).ok();
    }

    #[test]
    fn audit_coverage_reports_present_missing_and_extra() {
        use crate::config::SweepConfig;
        let cfg = SweepConfig {
            models: vec!["i".into()],
            methods: vec![Method::FullRecompute, Method::FixedChunk(8)],
            seeds: vec![7],
            iterations: 10,
        };
        let scenarios = crate::sweep::grid::expand(&cfg).unwrap();
        assert_eq!(scenarios.len(), 2);
        let h0 = scenario_hash(&scenarios[0].run, &seq());

        let path = tmp_path("audit");
        {
            let mut w = CheckpointWriter::create(&path, Some(&seq())).unwrap();
            w.record(&h0, &sample_result(0, 7)).unwrap();
            // a foreign record (other grid / other sampler)
            w.record("ffffffffffffffff", &sample_result(9, 9)).unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        let audit = audit_coverage(&cfg, &seq(), &set).unwrap();
        assert_eq!(audit.planned, 2);
        assert_eq!(audit.present, 1);
        assert_eq!(audit.extra, 1);
        assert!(!audit.complete());
        assert_eq!(audit.missing.len(), 1);
        assert_eq!(audit.missing[0].0, scenarios[1].index);
        assert_eq!(audit.missing[0].1, scenario_hash(&scenarios[1].run, &seq()));

        // the same rows under the other sampler cover nothing: the
        // sampler tag is part of the identity
        let fast = audit_coverage(&cfg, &TraceProvenance::current(RouterSampler::Split), &set).unwrap();
        assert_eq!(fast.present, 0);
        assert_eq!(fast.missing.len(), 2);
        assert_eq!(fast.extra, 2);

        // complete set audits clean
        {
            let mut w = CheckpointWriter::append(&path, Some(&seq())).unwrap();
            w.record(&scenario_hash(&scenarios[1].run, &seq()), &sample_result(1, 7))
                .unwrap();
        }
        let set = CheckpointSet::load(std::slice::from_ref(&path)).unwrap();
        let audit = audit_coverage(&cfg, &seq(), &set).unwrap();
        assert!(audit.complete());
        assert_eq!(audit.present, 2);
        std::fs::remove_file(&path).ok();
    }
}
