//! Summary statistics used by traces, benches and the imbalance
//! analyses (Fig. 2 reports per-layer min/mean/max; the benches add
//! percentiles).

/// Online + batch summary of a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { values: Vec::new() }
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(it: I) -> Self {
        Summary { values: it.into_iter().collect() }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.values.len() < 2 {
            return 0.0;
        }
        (self.values.iter().map(|v| (v - m).powi(2)).sum::<f64>()
            / (self.values.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (q / 100.0) * (sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = pos - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Coefficient of variation — the imbalance measure used in the
    /// routing analyses (0 = perfectly balanced).
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            return 0.0;
        }
        self.std() / m
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values
/// outside clamp to the edge buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, v: f64) {
        let bins = self.counts.len();
        let t = ((v - self.lo) / (self.hi - self.lo) * bins as f64).floor();
        let idx = (t.max(0.0) as usize).min(bins - 1);
        self.counts[idx] += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_iter([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from_iter([0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn p50_of_odd_sample_is_median() {
        let s = Summary::from_iter([9.0, 1.0, 5.0]);
        assert_eq!(s.p50(), 5.0);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let s = Summary::from_iter([3.0, 3.0, 3.0]);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn cv_measures_imbalance() {
        let balanced = Summary::from_iter([10.0, 10.0, 10.0, 10.0]);
        let skewed = Summary::from_iter([37.0, 1.0, 1.0, 1.0]);
        assert!(skewed.cv() > balanced.cv() + 1.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.99);
        h.add(-5.0); // clamps into bin 0
        h.add(50.0); // clamps into bin 9
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[9], 2);
        assert_eq!(h.total(), 4);
    }
}
