//! Property tests for the sampler edge cases the chunked batch
//! kernels and the trace store must survive:
//!
//! * `gamma_batch` with alpha < 1 (the Marsaglia–Tsang boost path —
//!   the routing regime's concentrations live here) must replay the
//!   per-draw `gamma` stream bit for bit, including the generator's
//!   end state;
//! * `multinomial_split` with `n = 0` trials and with `k = 1`
//!   categories must match the sequential sampler exactly (counts and
//!   stream consumption);
//! * empty-iteration traces (`iterations = 0`) must round-trip the
//!   on-disk trace store bit-exactly.

use memfine::config::{model_i, paper_parallel};
use memfine::prop::{assert_prop, Gen, PairGen, U64Range};
use memfine::router::GatingSim;
use memfine::trace::{trace_key, SharedRoutingTrace, TraceProvenance, TraceStore};
use memfine::util::rng::Rng;

/// Shapes strictly below 1 (mapped from a u64 grid): the boost path.
#[derive(Clone, Debug)]
struct SubOneShape;

impl Gen for SubOneShape {
    type Value = (u64, f64);
    fn generate(&self, rng: &mut Rng) -> (u64, f64) {
        let seed = rng.below(1 << 20);
        // alpha in (0, 1): from 1e-3 (deep-layer chaos) up to 0.999
        let alpha = (1 + rng.below(999)) as f64 / 1000.0;
        (seed, alpha)
    }
}

#[test]
fn prop_gamma_batch_sub_one_alpha_bit_identical() {
    assert_prop(211, 40, &SubOneShape, |&(seed, alpha): &(u64, f64)| {
        if !(0.0..1.0).contains(&alpha) || alpha <= 0.0 {
            return Err(format!("generator produced alpha {alpha}"));
        }
        // odd length exercises the chunk tail
        let n = 257;
        let mut a = Rng::new(seed);
        let per_draw: Vec<f64> = (0..n).map(|_| a.gamma(alpha)).collect();
        let mut b = Rng::new(seed);
        let mut batched = vec![0.0; n];
        b.gamma_batch(alpha, &mut batched);
        for (i, (x, y)) in per_draw.iter().zip(&batched).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!(
                    "alpha {alpha} seed {seed} draw {i}: {x} != {y}"
                ));
            }
        }
        if a.next_u64() != b.next_u64() {
            return Err(format!("alpha {alpha} seed {seed}: end states differ"));
        }
        Ok(())
    });
}

#[test]
fn prop_multinomial_split_zero_trials_and_single_category() {
    // n = 0 over any category count: all-zero counts, no stream
    // consumption difference vs the sequential sampler.
    assert_prop(
        223,
        40,
        &PairGen(U64Range(0, 1 << 20), U64Range(1, 64)),
        |&(seed, k): &(u64, u64)| {
            let probs = Rng::new(seed).dirichlet_symmetric(0.5, k as usize);
            let mut a = Rng::new(seed ^ 0xF00D);
            let mut b = Rng::new(seed ^ 0xF00D);
            let split = a.multinomial_split(0, &probs);
            let seq = b.multinomial(0, &probs);
            if split != seq || split.iter().sum::<u64>() != 0 {
                return Err(format!("k {k}: zero-trial draws differ: {split:?} vs {seq:?}"));
            }
            if a.next_u64() != b.next_u64() {
                return Err(format!("k {k}: zero-trial stream consumption differs"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_multinomial_split_one_category() {
    // k = 1 over any trial count: everything lands on the only
    // category, bit-identically to the sequential sampler, with no
    // generator words consumed by either.
    assert_prop(
        227,
        40,
        &PairGen(U64Range(0, 17), U64Range(0, 1 << 20)),
        |&(seed, n): &(u64, u64)| {
            let probs = [1.0f64];
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let split = a.multinomial_split(n, &probs);
            let seq = b.multinomial(n, &probs);
            if split != vec![n] || seq != vec![n] {
                return Err(format!("n {n}: single-category counts wrong: {split:?} / {seq:?}"));
            }
            if a.next_u64() != b.next_u64() {
                return Err(format!("n {n}: single-category consumption differs"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_empty_iteration_traces_roundtrip_the_store() {
    let mut dir = std::env::temp_dir();
    dir.push(format!("memfine-prop-store-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = TraceStore::open(&dir).unwrap();
    assert_prop(229, 20, &U64Range(0, 1 << 20), |&seed: &u64| {
        let gating = GatingSim::new(model_i(), paper_parallel(), seed);
        let trace = SharedRoutingTrace::generate(&gating, 0);
        if !trace.records.is_empty() {
            return Err("empty-iteration trace drew records".into());
        }
        let key = trace_key(
            &trace.model,
            &trace.parallel,
            seed,
            0,
            &TraceProvenance::default(),
        );
        store.save(&key, &trace).map_err(|e| format!("save: {e}"))?;
        let back = store
            .load(&key, &trace.model, &trace.parallel, seed, 0)
            .ok_or("empty trace missed the cache")?;
        if back.records.is_empty() && back.seed == seed && back.iterations == 0 {
            Ok(())
        } else {
            Err(format!(
                "roundtrip mutated the trace: seed {} iterations {} records {}",
                back.seed,
                back.iterations,
                back.records.len()
            ))
        }
    });
    std::fs::remove_dir_all(&dir).ok();
}
