//! Deterministic worker pool for embarrassingly-parallel scenario
//! grids — a work-stealing runtime in the FastFlow style of ppl's
//! `thread_pool`/`channel` split, reduced to std.
//!
//! **Scheduling** ([`Schedule`]): the default is per-worker local
//! deques seeded round-robin from the grid. Owners pop their own deque
//! LIFO (the hot tail stays local); an idle worker steals FIFO (the
//! oldest, coldest job) from a randomized victim, backing off
//! exponentially while the whole pool is out of work. The pre-stealing
//! design — one shared injector queue every worker pulls from — stays
//! selectable as the A/B reference ([`Schedule::Injector`]).
//!
//! **Result transport** ([`ResultChannel`]): finished results flow
//! back to a consumer on the caller's thread through a pluggable
//! backend. The default is an in-tree **bounded** Mutex+Condvar
//! channel sized ~4× the worker count, so a slow consumer (checkpoint
//! append + flush per scenario) backpressures the workers instead of
//! buffering the whole grid in memory; `std::sync::mpsc` (unbounded,
//! the original behaviour) remains selectable.
//!
//! Scheduling order is nondeterministic by design — stealing makes it
//! *more* so — but the *output* is not: every job carries its index,
//! jobs are pure functions of their input, and the consumer keys
//! everything by that index, so any index-keyed reduction is
//! bit-identical for any worker count, schedule, channel backend, or
//! core-pinning choice. The sweep engine's determinism guarantee rests
//! on exactly this property, and the chaos tests below attack it with
//! forced steal storms.
//!
//! Two entry points: [`parallel_for_each_indexed`] streams each result
//! to a caller-side consumer as it lands (the million-scenario path —
//! nothing is retained in the pool), and [`parallel_map_indexed`]
//! collects into an input-ordered `Vec` on top of it. The `_with`
//! variants take a full [`PoolConfig`] and surface [`PoolStats`]
//! (per-worker jobs, steal counts, queue depths, busy time) — which
//! are execution facts and must NEVER be folded into sweep artifacts.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// How jobs are distributed over the worker threads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Schedule {
    /// Per-worker deques seeded round-robin; owners pop LIFO, idle
    /// workers steal FIFO from randomized victims (the default).
    #[default]
    Stealing,
    /// The pre-stealing design: one shared injector queue every worker
    /// pulls from. Kept selectable as the A/B reference the stealing
    /// runtime is pinned byte-identical against.
    Injector,
}

impl Schedule {
    pub fn parse(tag: &str) -> Result<Self> {
        match tag {
            "stealing" => Ok(Schedule::Stealing),
            "injector" => Ok(Schedule::Injector),
            other => Err(Error::Cli(format!(
                "unknown pool schedule '{other}' (stealing|injector)"
            ))),
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            Schedule::Stealing => "stealing",
            Schedule::Injector => "injector",
        }
    }
}

/// Which backend carries finished results back to the caller thread.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ChannelKind {
    /// In-tree bounded channel (capacity ~4× workers unless
    /// overridden): producers block when the consumer falls behind, so
    /// finished results can never pile up unboundedly (the default).
    #[default]
    Bounded,
    /// `std::sync::mpsc` — unbounded, never blocks producers (the
    /// pre-backpressure behaviour, kept selectable for A/B).
    StdMpsc,
}

impl ChannelKind {
    pub fn parse(tag: &str) -> Result<Self> {
        match tag {
            "bounded" => Ok(ChannelKind::Bounded),
            "std" | "mpsc" => Ok(ChannelKind::StdMpsc),
            other => Err(Error::Cli(format!(
                "unknown channel backend '{other}' (bounded|std)"
            ))),
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            ChannelKind::Bounded => "bounded",
            ChannelKind::StdMpsc => "std",
        }
    }
}

/// Full execution spec of one pool invocation. Everything here is
/// execution-only: artifact bytes must come out identical for any
/// choice of these knobs (the chaos tests pin it).
#[derive(Clone, Debug, Default)]
pub struct PoolConfig {
    /// Worker threads (clamped to `[1, jobs]`; 0 behaves as 1).
    pub workers: usize,
    pub schedule: Schedule,
    pub channel: ChannelKind,
    /// Bounded-channel capacity (0 = auto: 4 × workers).
    pub channel_capacity: usize,
    /// Best-effort pin of worker `k` to core `k % cores` (Linux
    /// `sched_setaffinity`; a no-op elsewhere). A failed pin is a
    /// performance hint missed, never an error.
    pub pin_cores: bool,
    /// Chaos knob for the determinism tests: seed the entire grid into
    /// worker 0's deque, so every other worker can only make progress
    /// by stealing (a forced steal storm).
    pub steal_storm: bool,
}

impl PoolConfig {
    /// The production defaults for `workers` threads.
    pub fn with_workers(workers: usize) -> Self {
        PoolConfig { workers, ..PoolConfig::default() }
    }
}

/// Per-worker execution counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Jobs this worker executed.
    pub jobs: u64,
    /// Steal attempts: times a victim's deque was probed (stealing
    /// schedule only).
    pub steals_attempted: u64,
    /// Steal attempts that yielded a job.
    pub steals_succeeded: u64,
    /// Deepest the queue this worker popped from ever was (its own
    /// deque under stealing; the shared injector under `Injector`).
    pub max_queue_depth: usize,
    /// Nanoseconds spent inside job bodies.
    pub busy_ns: u64,
    /// Whether this worker's core pin took effect.
    pub pinned: bool,
}

/// What one pool invocation did — execution facts only, surfaced for
/// stderr and bench reporting and NEVER part of sweep artifacts (the
/// determinism contract: scheduling cannot leak into artifact bytes).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PoolStats {
    pub schedule: Schedule,
    pub channel: ChannelKind,
    /// One entry per worker thread (a single entry for serial runs).
    pub workers: Vec<WorkerStats>,
    /// Wall-clock nanoseconds of the whole pool run.
    pub wall_ns: u64,
    /// Sends that had to wait for channel capacity — the backpressure
    /// stalls a slow consumer inflicted on the workers (bounded
    /// channel only; 0 for serial runs and the unbounded backend).
    pub blocked_sends: u64,
}

impl PoolStats {
    pub fn jobs_total(&self) -> u64 {
        self.workers.iter().map(|w| w.jobs).sum()
    }

    pub fn steals_attempted(&self) -> u64 {
        self.workers.iter().map(|w| w.steals_attempted).sum()
    }

    pub fn steals_succeeded(&self) -> u64 {
        self.workers.iter().map(|w| w.steals_succeeded).sum()
    }

    pub fn pinned_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.pinned).count()
    }

    pub fn max_queue_depth(&self) -> usize {
        self.workers.iter().map(|w| w.max_queue_depth).max().unwrap_or(0)
    }

    pub fn busy_ns_total(&self) -> u64 {
        self.workers.iter().map(|w| w.busy_ns).sum()
    }

    /// Straggler overhead: wall clock minus the perfectly balanced
    /// lower bound (total busy time / workers). This is the tail
    /// latency a scheduler can actually fight — 0 means every worker
    /// stayed busy until the last job finished. Busy time is sampled
    /// inside job bodies while wall brackets the whole run, so on
    /// coarse clocks `busy/workers` can exceed `wall`; the saturating
    /// subtraction clamps that at 0 instead of wrapping to ~u64::MAX.
    pub fn tail_latency_ns(&self) -> u64 {
        let n = self.workers.len().max(1) as u64;
        self.wall_ns.saturating_sub(self.busy_ns_total() / n)
    }
}

/// Result transport between the workers and the caller-side consumer —
/// the FastFlow-style seam (ppl keeps its channel backends behind one
/// trait for the same reason). Exactly one consumer calls `recv`; each
/// of the N producers calls `send` any number of times and `done`
/// exactly once (a drop guard makes that hold even under panics).
pub trait ResultChannel<R>: Sync {
    /// Deliver one result. May block (bounded backend, consumer
    /// behind); silently drops the result if the consumer is gone.
    fn send(&self, item: R);
    /// One producer finished. After the last `done`, `recv` drains the
    /// queue and then returns `None`.
    fn done(&self);
    /// Next result, blocking; `None` once all producers are done and
    /// the queue is drained.
    fn recv(&self) -> Option<R>;
    /// Consumer is gone: wake any blocked producer and make further
    /// sends no-ops, so an unwinding consumer can never deadlock the
    /// pool.
    fn close(&self);
    /// Sends that had to wait for capacity (backpressure stalls).
    /// Backends without backpressure report 0.
    fn blocked_sends(&self) -> u64 {
        0
    }
}

/// Bounded MPSC built on a `Mutex<VecDeque>` and two condvars. `send`
/// blocks while the queue is at capacity — the backpressure that keeps
/// a slow consumer from buffering the whole grid.
pub struct BoundedChannel<R> {
    state: Mutex<BoundedState<R>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    blocked: AtomicU64,
}

struct BoundedState<R> {
    queue: VecDeque<R>,
    producers: usize,
    closed: bool,
}

impl<R> BoundedChannel<R> {
    pub fn new(capacity: usize, producers: usize) -> Self {
        let capacity = capacity.max(1);
        BoundedChannel {
            state: Mutex::new(BoundedState {
                queue: VecDeque::with_capacity(capacity),
                producers,
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
            blocked: AtomicU64::new(0),
        }
    }
}

impl<R: Send> ResultChannel<R> for BoundedChannel<R> {
    fn send(&self, item: R) {
        let mut st = self.state.lock().unwrap();
        if st.queue.len() >= self.capacity && !st.closed {
            // Counted once per send that waits, however many wakeups
            // it takes — "how often did backpressure bite", not "how
            // many condvar spins".
            self.blocked.fetch_add(1, Ordering::Relaxed);
            while st.queue.len() >= self.capacity && !st.closed {
                st = self.not_full.wait(st).unwrap();
            }
        }
        if st.closed {
            return;
        }
        st.queue.push_back(item);
        drop(st);
        self.not_empty.notify_one();
    }

    fn done(&self) {
        let mut st = self.state.lock().unwrap();
        st.producers -= 1;
        let last = st.producers == 0;
        drop(st);
        if last {
            self.not_empty.notify_all();
        }
    }

    fn recv(&self) -> Option<R> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.queue.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.producers == 0 {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_full.notify_all();
    }

    fn blocked_sends(&self) -> u64 {
        self.blocked.load(Ordering::Relaxed)
    }
}

/// `std::sync::mpsc` behind the trait: unbounded, non-blocking sends.
/// `mpsc::Sender` is only `Sync` on newer std, so the sender lives
/// behind a mutex with explicit producer counting — the last `done`
/// drops it, which is what unblocks `recv`. A send after the receiver
/// unwound simply errors into the void, matching `close`'s contract.
pub struct StdMpscChannel<R> {
    tx: Mutex<Option<mpsc::Sender<R>>>,
    rx: Mutex<mpsc::Receiver<R>>,
    producers: AtomicUsize,
}

impl<R> StdMpscChannel<R> {
    pub fn new(producers: usize) -> Self {
        let (tx, rx) = mpsc::channel();
        StdMpscChannel {
            tx: Mutex::new(Some(tx)),
            rx: Mutex::new(rx),
            producers: AtomicUsize::new(producers),
        }
    }
}

impl<R: Send> ResultChannel<R> for StdMpscChannel<R> {
    fn send(&self, item: R) {
        if let Some(tx) = self.tx.lock().unwrap().as_ref() {
            tx.send(item).ok();
        }
    }

    fn done(&self) {
        if self.producers.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.tx.lock().unwrap().take();
        }
    }

    fn recv(&self) -> Option<R> {
        self.rx.lock().unwrap().recv().ok()
    }

    fn close(&self) {}
}

/// Calls `done` on drop, so a panicking job body still releases the
/// consumer: `recv` must see the producer count reach zero even when a
/// worker unwinds mid-job.
struct DoneGuard<'a, R>(&'a dyn ResultChannel<R>);

impl<R> Drop for DoneGuard<'_, R> {
    fn drop(&mut self) {
        self.0.done();
    }
}

/// Marks the channel closed on drop: if the consumer unwinds mid-drain
/// (a reducer invariant panic), blocked bounded-channel producers must
/// wake and bail out instead of deadlocking the thread scope.
struct CloseGuard<'a, R>(&'a dyn ResultChannel<R>);

impl<R> Drop for CloseGuard<'_, R> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Best-effort: pin the calling thread to core `worker % cores` via
/// raw `sched_setaffinity` (pid 0 = calling thread; the crate links no
/// libc crate, but std itself links libc on Linux). Returns whether
/// the pin took effect. Failure is never an error — pinning is a
/// cache-locality hint, and the determinism contract holds either way.
#[cfg(target_os = "linux")]
fn pin_current_thread(worker: usize) -> bool {
    // glibc cpu_set_t: a 1024-bit mask
    #[repr(C)]
    struct CpuSet {
        bits: [u64; 16],
    }
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    let cores = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1)
        .min(16 * 64);
    let core = worker % cores;
    let mut set = CpuSet { bits: [0; 16] };
    set.bits[core / 64] |= 1u64 << (core % 64);
    unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_worker: usize) -> bool {
    false
}

type JobQueue<T> = Mutex<VecDeque<(usize, T)>>;

/// Run `f` over `items` on `workers` threads under the default
/// [`PoolConfig`] (stealing schedule, bounded channel), streaming
/// every result to `consume` on the **caller's thread** as it arrives.
/// `f` receives `(index, item)`; `consume` receives `(index, result)`
/// in completion order, which is nondeterministic for `workers > 1` —
/// consumers must key on the index (the sweep reducer folds by grid
/// index for exactly this reason). With `workers <= 1` the loop runs
/// inline in input order with no threads spawned; serial and parallel
/// deliver the same (index, result) multiset.
pub fn parallel_for_each_indexed<T, R, F, C>(items: Vec<T>, workers: usize, f: F, consume: C)
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
    C: FnMut(usize, R),
{
    parallel_for_each_indexed_with(items, &PoolConfig::with_workers(workers), f, consume);
}

/// [`parallel_for_each_indexed`] under an explicit [`PoolConfig`],
/// returning the run's [`PoolStats`].
pub fn parallel_for_each_indexed_with<T, R, F, C>(
    items: Vec<T>,
    cfg: &PoolConfig,
    f: F,
    mut consume: C,
) -> PoolStats
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
    C: FnMut(usize, R),
{
    let n = items.len();
    let mut stats = PoolStats {
        schedule: cfg.schedule,
        channel: cfg.channel,
        workers: Vec::new(),
        wall_ns: 0,
        blocked_sends: 0,
    };
    if n == 0 {
        return stats;
    }
    let workers = cfg.workers.max(1).min(n);
    let t0 = Instant::now();
    if workers == 1 {
        // Inline serial path: input order, no threads, no channel.
        let mut ws = WorkerStats { max_queue_depth: n, ..WorkerStats::default() };
        for (i, t) in items.into_iter().enumerate() {
            let job_t0 = Instant::now();
            let r = f(i, t);
            ws.busy_ns += job_t0.elapsed().as_nanos() as u64;
            ws.jobs += 1;
            consume(i, r);
        }
        stats.workers.push(ws);
        stats.wall_ns = t0.elapsed().as_nanos() as u64;
        return stats;
    }

    // Channel backend behind one trait object; both candidates live on
    // this frame so the scoped workers can borrow whichever was built.
    let bounded;
    let unbounded;
    let chan: &dyn ResultChannel<(usize, R)> = match cfg.channel {
        ChannelKind::Bounded => {
            let cap = if cfg.channel_capacity == 0 {
                4 * workers
            } else {
                cfg.channel_capacity
            };
            bounded = BoundedChannel::new(cap, workers);
            &bounded
        }
        ChannelKind::StdMpsc => {
            unbounded = StdMpscChannel::new(workers);
            &unbounded
        }
    };

    stats.workers = match cfg.schedule {
        Schedule::Stealing => {
            run_stealing(items, workers, cfg.pin_cores, cfg.steal_storm, chan, &f, &mut consume)
        }
        Schedule::Injector => run_injector(items, workers, cfg.pin_cores, chan, &f, &mut consume),
    };
    stats.wall_ns = t0.elapsed().as_nanos() as u64;
    stats.blocked_sends = chan.blocked_sends();
    stats
}

/// The work-stealing runtime: per-worker deques (owner pops LIFO,
/// thieves steal FIFO), randomized victim order, exponential backoff
/// when the whole pool runs dry.
fn run_stealing<T, R, F, C>(
    items: Vec<T>,
    workers: usize,
    pin_cores: bool,
    steal_storm: bool,
    chan: &dyn ResultChannel<(usize, R)>,
    f: &F,
    consume: &mut C,
) -> Vec<WorkerStats>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
    C: FnMut(usize, R),
{
    let n = items.len();
    // Seed round-robin so every worker starts with local work — or,
    // under the steal-storm chaos knob, everything into worker 0 so
    // the rest can only make progress by stealing.
    let queues: Vec<JobQueue<T>> = (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    {
        let mut seeded: Vec<_> = queues.iter().map(|q| q.lock().unwrap()).collect();
        for (i, t) in items.into_iter().enumerate() {
            let dst = if steal_storm { 0 } else { i % workers };
            seeded[dst].push_back((i, t));
        }
    }
    // Termination: jobs *taken*, not completed — decremented at claim
    // time, so a panicking job can never strand the other workers in
    // the idle loop.
    let remaining = AtomicUsize::new(n);
    let queues = &queues;
    let remaining = &remaining;
    let mut per_worker = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|k| {
                scope.spawn(move || {
                    let _done = DoneGuard(chan);
                    let pinned = pin_cores && pin_current_thread(k);
                    steal_loop(k, queues, remaining, chan, f, pinned)
                })
            })
            .collect();
        let _close = CloseGuard(chan);
        while let Some((i, r)) = chan.recv() {
            consume(i, r);
        }
        for h in handles {
            match h.join() {
                Ok(ws) => per_worker.push(ws),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
    });
    per_worker
}

fn steal_loop<T, R, F>(
    k: usize,
    queues: &[JobQueue<T>],
    remaining: &AtomicUsize,
    chan: &dyn ResultChannel<(usize, R)>,
    f: &F,
    pinned: bool,
) -> WorkerStats
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let mut ws = WorkerStats { pinned, ..WorkerStats::default() };
    // Deterministic per-worker xorshift for victim choice: scheduling
    // may be as random as it likes — results are keyed by index, so
    // none of this can reach the artifact.
    let mut rng = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(k as u64 + 1) | 1;
    let mut idle_rounds = 0u32;
    loop {
        let job = {
            let mut q = queues[k].lock().unwrap();
            ws.max_queue_depth = ws.max_queue_depth.max(q.len());
            // owner end: LIFO keeps the hot tail local
            q.pop_back()
        };
        let job = match job {
            Some(j) => Some(j),
            None => try_steal(k, queues, &mut rng, &mut ws),
        };
        match job {
            Some((i, t)) => {
                idle_rounds = 0;
                remaining.fetch_sub(1, Ordering::AcqRel);
                let job_t0 = Instant::now();
                let r = f(i, t);
                ws.busy_ns += job_t0.elapsed().as_nanos() as u64;
                ws.jobs += 1;
                chan.send((i, r));
            }
            None => {
                if remaining.load(Ordering::Acquire) == 0 {
                    break;
                }
                // Exponential backoff while out of work: spin-yield
                // first, then sleep up to ~1 ms. Taken-but-running
                // jobs may still be in flight elsewhere, so this loop
                // only ends when every job has been claimed.
                idle_rounds = (idle_rounds + 1).min(10);
                if idle_rounds <= 3 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(1u64 << idle_rounds));
                }
            }
        }
    }
    ws
}

/// One randomized sweep over the other workers' deques, stealing from
/// the FIFO end (the oldest job — the one its owner would reach last).
fn try_steal<T>(
    k: usize,
    queues: &[JobQueue<T>],
    rng: &mut u64,
    ws: &mut WorkerStats,
) -> Option<(usize, T)> {
    let workers = queues.len();
    if workers <= 1 {
        return None;
    }
    let start = (xorshift(rng) as usize) % workers;
    for off in 0..workers {
        let victim = (start + off) % workers;
        if victim == k {
            continue;
        }
        ws.steals_attempted += 1;
        if let Some(job) = queues[victim].lock().unwrap().pop_front() {
            ws.steals_succeeded += 1;
            return Some(job);
        }
    }
    None
}

fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x
}

/// The pre-stealing design, kept as the A/B reference: one shared
/// injector queue every worker pulls from (every dispatch serialises
/// on its lock — the contention stealing removes).
fn run_injector<T, R, F, C>(
    items: Vec<T>,
    workers: usize,
    pin_cores: bool,
    chan: &dyn ResultChannel<(usize, R)>,
    f: &F,
    consume: &mut C,
) -> Vec<WorkerStats>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
    C: FnMut(usize, R),
{
    let injector: JobQueue<T> = Mutex::new(items.into_iter().enumerate().collect());
    let injector = &injector;
    let mut per_worker = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|k| {
                scope.spawn(move || {
                    let _done = DoneGuard(chan);
                    let pinned = pin_cores && pin_current_thread(k);
                    let mut ws = WorkerStats { pinned, ..WorkerStats::default() };
                    loop {
                        let job = {
                            let mut q = injector.lock().unwrap();
                            ws.max_queue_depth = ws.max_queue_depth.max(q.len());
                            q.pop_front()
                        };
                        match job {
                            Some((i, t)) => {
                                let job_t0 = Instant::now();
                                let r = f(i, t);
                                ws.busy_ns += job_t0.elapsed().as_nanos() as u64;
                                ws.jobs += 1;
                                chan.send((i, r));
                            }
                            None => break,
                        }
                    }
                    ws
                })
            })
            .collect();
        let _close = CloseGuard(chan);
        while let Some((i, r)) = chan.recv() {
            consume(i, r);
        }
        for h in handles {
            match h.join() {
                Ok(ws) => per_worker.push(ws),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
    });
    per_worker
}

/// Map `f` over `items` on `workers` threads, preserving input order
/// in the output. Collect-all convenience over
/// [`parallel_for_each_indexed`]; prefer the streaming form when
/// results are large or the grid is (the sweep engine does).
pub fn parallel_map_indexed<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    parallel_map_indexed_with(items, &PoolConfig::with_workers(workers), f).0
}

/// [`parallel_map_indexed`] under an explicit [`PoolConfig`],
/// returning the run's [`PoolStats`] alongside the mapped values.
pub fn parallel_map_indexed_with<T, R, F>(
    items: Vec<T>,
    cfg: &PoolConfig,
    f: F,
) -> (Vec<R>, PoolStats)
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let stats = parallel_for_each_indexed_with(items, cfg, f, |i, r| deliver_once(&mut out, i, r));
    let collected = out
        .into_iter()
        .map(|r| r.expect("every job delivers exactly one result"))
        .collect();
    (collected, stats)
}

/// THE delivery invariant, enforced in **every** build: a
/// double-delivered index would silently overwrite `out[i]` and
/// corrupt results if this were a `debug_assert!` (it once was). Both
/// runtimes' collect path routes through here, and the sweep reducer
/// enforces the same invariant independently on the streaming path.
fn deliver_once<R>(out: &mut [Option<R>], i: usize, r: R) {
    assert!(out[i].is_none(), "job {i} delivered twice");
    out[i] = Some(r);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map_indexed(items, 4, |i, x| {
            assert_eq!(i as u64, x);
            x * x
        });
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let work = |_: usize, x: u64| x.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17);
        let items: Vec<u64> = (0..64).collect();
        let serial = parallel_map_indexed(items.clone(), 1, work);
        for workers in [2, 3, 8, 64, 200] {
            let parallel = parallel_map_indexed(items.clone(), workers, work);
            assert_eq!(serial, parallel, "workers={workers}");
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<u64> = parallel_map_indexed(Vec::<u64>::new(), 8, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item_more_workers_than_jobs() {
        let out = parallel_map_indexed(vec![41u64], 16, |_, x| x + 1);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn streaming_delivers_every_result_exactly_once() {
        for workers in [1usize, 4, 16] {
            let items: Vec<u64> = (0..50).collect();
            let mut seen = vec![0u32; 50];
            let mut sum = 0u64;
            parallel_for_each_indexed(items, workers, |_, x| x * 3, |i, r| {
                seen[i] += 1;
                sum += r;
            });
            assert!(seen.iter().all(|&c| c == 1), "workers={workers}: {seen:?}");
            assert_eq!(sum, (0..50u64).map(|x| x * 3).sum::<u64>());
        }
    }

    #[test]
    fn streaming_serial_is_input_order() {
        let mut order = Vec::new();
        parallel_for_each_indexed((0..10u64).collect(), 1, |_, x| x, |i, _| order.push(i));
        assert_eq!(order, (0..10).collect::<Vec<usize>>());
    }

    #[test]
    fn uneven_job_costs_all_complete() {
        // Jobs with wildly different costs: the pool rebalances and
        // every result still lands at its index.
        let items: Vec<u64> = (0..32).collect();
        let out = parallel_map_indexed(items, 4, |_, x| {
            let spin = if x % 7 == 0 { 200_000 } else { 10 };
            let mut acc = x;
            for i in 0..spin {
                acc = acc.wrapping_add(i).rotate_left(1);
            }
            (x, acc)
        });
        for (i, (x, _)) in out.iter().enumerate() {
            assert_eq!(i as u64, *x);
        }
    }

    /// Pseudo-random per-job spin keyed on the index: adversarially
    /// uneven costs, deterministic across runs.
    fn chaos_work(i: usize, x: u64) -> u64 {
        let mut h = (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
        h ^= h >> 29;
        let spin = h % 20_000;
        let mut acc = x;
        for j in 0..spin {
            acc = acc.wrapping_add(j).rotate_left(1);
        }
        acc ^ h
    }

    #[test]
    fn chaos_adversarial_stealing_is_byte_identical_to_serial() {
        // THE determinism contract under attack: forced steal storms
        // (all jobs seeded to worker 0), randomized per-job costs,
        // 2/8/64 workers, pinned and unpinned, both channel backends —
        // the output must equal the workers=1 run exactly.
        let items: Vec<u64> = (0..200).collect();
        let serial = parallel_map_indexed(items.clone(), 1, chaos_work);
        for workers in [2usize, 8, 64] {
            for pin_cores in [false, true] {
                for steal_storm in [false, true] {
                    for channel in [ChannelKind::Bounded, ChannelKind::StdMpsc] {
                        let cfg = PoolConfig {
                            workers,
                            pin_cores,
                            steal_storm,
                            channel,
                            ..PoolConfig::default()
                        };
                        let label = format!(
                            "workers={workers} pin={pin_cores} storm={steal_storm} channel={}",
                            channel.tag()
                        );
                        let (out, stats) =
                            parallel_map_indexed_with(items.clone(), &cfg, chaos_work);
                        assert_eq!(out, serial, "{label}");
                        assert_eq!(stats.jobs_total(), items.len() as u64, "{label}");
                    }
                }
            }
        }
    }

    #[test]
    fn injector_and_stealing_schedules_agree() {
        let items: Vec<u64> = (0..128).collect();
        let serial = parallel_map_indexed(items.clone(), 1, chaos_work);
        for workers in [2usize, 8] {
            for schedule in [Schedule::Injector, Schedule::Stealing] {
                let cfg = PoolConfig { workers, schedule, ..PoolConfig::default() };
                let (out, stats) = parallel_map_indexed_with(items.clone(), &cfg, chaos_work);
                assert_eq!(out, serial, "workers={workers} schedule={}", schedule.tag());
                assert_eq!(stats.schedule, schedule);
                assert_eq!(stats.jobs_total(), items.len() as u64);
            }
        }
    }

    #[test]
    fn pool_stats_add_up_and_steals_happen_under_skew() {
        // Steal storm at 8 workers: everything starts on worker 0, so
        // the other 7 can only make progress by stealing.
        let items: Vec<u64> = (0..200).collect();
        let cfg = PoolConfig { workers: 8, steal_storm: true, ..PoolConfig::default() };
        let (_, stats) = parallel_map_indexed_with(items, &cfg, chaos_work);
        assert_eq!(stats.workers.len(), 8);
        assert_eq!(stats.jobs_total(), 200);
        assert!(stats.steals_attempted() >= stats.steals_succeeded());
        assert!(stats.steals_succeeded() > 0, "steal storm produced no steals");
        // worker 0's deque held the whole grid at its first pop
        assert_eq!(stats.max_queue_depth(), 200);
        assert!(stats.wall_ns > 0);
        assert!(stats.busy_ns_total() > 0);
        assert!(stats.tail_latency_ns() <= stats.wall_ns);
    }

    #[test]
    fn tail_latency_clamps_to_zero_when_busy_exceeds_wall() {
        // Busy time is measured per job body, wall around the whole
        // run: on coarse clocks busy/workers can exceed wall. The
        // subtraction must clamp at zero, never underflow.
        let stats = PoolStats {
            wall_ns: 1_000,
            workers: vec![
                WorkerStats { busy_ns: 4_000, ..WorkerStats::default() },
                WorkerStats { busy_ns: 3_000, ..WorkerStats::default() },
            ],
            ..PoolStats::default()
        };
        assert_eq!(stats.tail_latency_ns(), 0);
        // the degenerate no-worker snapshot divides by max(1), not 0
        let empty = PoolStats { wall_ns: 5, ..PoolStats::default() };
        assert_eq!(empty.tail_latency_ns(), 5);
    }

    #[test]
    fn serial_stats_report_one_worker() {
        let cfg = PoolConfig::with_workers(1);
        let (out, stats) = parallel_map_indexed_with((0..10u64).collect(), &cfg, |_, x| x + 1);
        assert_eq!(out, (1..11).collect::<Vec<u64>>());
        assert_eq!(stats.workers.len(), 1);
        assert_eq!(stats.jobs_total(), 10);
        assert_eq!(stats.steals_attempted(), 0);
        assert_eq!(stats.pinned_workers(), 0);
        assert_eq!(stats.blocked_sends, 0);
    }

    #[test]
    fn bounded_channel_tiny_capacity_backpressures_without_loss() {
        // Capacity 1 with a deliberately slow consumer: producers must
        // block (not drop, not duplicate) and every result lands.
        let items: Vec<u64> = (0..64).collect();
        let cfg = PoolConfig { workers: 4, channel_capacity: 1, ..PoolConfig::default() };
        let mut seen = vec![0u32; 64];
        let stats = parallel_for_each_indexed_with(items, &cfg, |_, x| x, |i, r| {
            if i % 8 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
            assert_eq!(i as u64, r);
            seen[i] += 1;
        });
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        // capacity 1 against a sleeping consumer: backpressure must
        // actually have stalled some producer sends
        assert!(stats.blocked_sends > 0, "no backpressure recorded");
    }

    #[test]
    fn bounded_channel_done_drains_then_ends() {
        let chan: BoundedChannel<u64> = BoundedChannel::new(2, 1);
        chan.send(7);
        chan.send(8);
        chan.done();
        assert_eq!(chan.recv(), Some(7));
        assert_eq!(chan.recv(), Some(8));
        assert_eq!(chan.recv(), None);
    }

    #[test]
    #[should_panic(expected = "delivered twice")]
    fn duplicate_delivery_panics_in_all_builds() {
        let mut out: Vec<Option<u64>> = vec![None; 2];
        deliver_once(&mut out, 1, 10);
        deliver_once(&mut out, 1, 11);
    }

    #[test]
    fn pin_cores_is_best_effort_and_harmless() {
        let cfg = PoolConfig { workers: 2, pin_cores: true, ..PoolConfig::default() };
        let (out, stats) = parallel_map_indexed_with((0..20u64).collect(), &cfg, |_, x| x * 2);
        assert_eq!(out, (0..20u64).map(|x| x * 2).collect::<Vec<u64>>());
        // on Linux the pin should normally take; elsewhere it's a
        // no-op — either way the run completes and stats stay sane
        assert!(stats.pinned_workers() <= stats.workers.len());
    }
}
