//! Golden regression pins for the theoretical memory model on the
//! paper's Table 4 configuration (Model I/II, t=1 p=4 e=32, 64 GB,
//! α=0.98, BF16, 16 B/param + 10 GB overhead).
//!
//! These exact byte values encode Eq. 1 (static), Eq. 2 (activation),
//! Eq. 3 (budget/OOM) and Eq. 8 (token budget s'_max) as currently
//! calibrated. A refactor that shifts any of them silently re-derives
//! different Table 4 numbers — this suite turns that into a loud,
//! reviewable diff. If a change is *intentional*, update the constants
//! here together with the calibration notes in `config::paper_run`.

use memfine::config::{model_i, model_ii, paper_run, Method};
use memfine::memory::{fits, ActivationModel, StaticModel};

const GB: u64 = 1024 * 1024 * 1024;

fn budget(run: &memfine::config::RunConfig) -> u64 {
    (run.alpha * run.gpu_mem_bytes as f64) as u64
}

#[test]
fn golden_budget_eq3() {
    let run = paper_run(model_i(), Method::FullRecompute);
    assert_eq!(run.gpu_mem_bytes, 64 * GB);
    assert_eq!(budget(&run), 67_345_087_201);
}

#[test]
fn golden_static_model_eq1() {
    let run = paper_run(model_i(), Method::FullRecompute);
    assert_eq!(run.model.attention_params(), 174_063_616);
    let sta = StaticModel::new(&run);
    let params: Vec<u64> = (0..4).map(|r| sta.params_on_rank(r)).collect();
    assert_eq!(
        params,
        vec![2_268_512_256, 2_112_937_984, 2_112_937_984, 2_141_896_704]
    );
    let bytes: Vec<u64> = (0..4).map(|r| sta.bytes_on_rank(r)).collect();
    assert_eq!(
        bytes,
        vec![47_033_614_336, 44_544_425_984, 44_544_425_984, 45_007_765_504]
    );
    assert_eq!(sta.max_bytes(), 47_033_614_336);
}

#[test]
fn golden_static_model_ii_eq1() {
    let run = paper_run(model_ii(), Method::FullRecompute);
    let sta = StaticModel::new(&run);
    let bytes: Vec<u64> = (0..4).map(|r| sta.bytes_on_rank(r)).collect();
    assert_eq!(
        bytes,
        vec![29_454_827_520, 28_316_205_056, 27_640_922_112, 28_104_261_632]
    );
}

#[test]
fn golden_activation_model_eq2() {
    let run = paper_run(model_i(), Method::FullRecompute);
    let act = ActivationModel::new(&run);
    // Table 2 dense term (∝ s) and per-received-token MoE term (∝ s').
    assert_eq!(act.dense_bytes(), 698_351_616);
    assert_eq!(act.moe_bytes_per_token(), 36_864);
    // Eq. 2 at a fixed s': dense + s'·per_token.
    assert_eq!(act.layer_bytes(100_000), 4_384_751_616);
    assert_eq!(
        act.layer_bytes(100_000),
        act.dense_bytes() + 100_000 * act.moe_bytes_per_token()
    );
    // Fig. 2 theoretical peak: e·s·b·t_k.
    assert_eq!(act.s_prime_theoretical_peak(), 1_048_576);
}

#[test]
fn golden_token_budget_eq8() {
    let run = paper_run(model_i(), Method::FullRecompute);
    let act = ActivationModel::new(&run);
    let sta = StaticModel::new(&run);
    let b = budget(&run);
    let s_max: Vec<u64> = (0..4)
        .map(|r| act.s_prime_max(r, sta.bytes_on_rank(r), b, true))
        .collect();
    assert_eq!(s_max, vec![532_039, 599_563, 599_563, 586_994]);
}

#[test]
fn golden_m_g_multipliers() {
    let run = paper_run(model_i(), Method::FullRecompute);
    let m_g: Vec<u64> = (0..4).map(|r| run.parallel.m_g(r)).collect();
    assert_eq!(m_g, vec![7, 5, 3, 1]);
}

#[test]
fn golden_table4_feasibility_verdicts() {
    // The Table 4 qualitative outcomes, as Eq. 3 verdicts at the
    // theoretical worst case: Model I cannot host unchunked worst-case
    // routing, chunking by 8 rescues it.
    let run = paper_run(model_i(), Method::FullRecompute);
    let worst = ActivationModel::new(&run).s_prime_theoretical_peak();
    assert!(!fits(&run, worst, 1, true), "Model I worst case must OOM unchunked");
    assert!(fits(&run, worst, 8, true), "c=8 must rescue Model I worst case");
}
