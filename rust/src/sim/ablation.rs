//! Ablation studies over MemFine's design choices (DESIGN.md §4 calls
//! these out; `cargo bench --bench ablations` prints them):
//!
//! * **Bin granularity** — MACT with fine bins [1..8] vs the paper's
//!   [1,2,4,8] vs degenerate single bins: memory/TGS trade-off of the
//!   threshold method ("introducing (8) and (9) would increase the
//!   computational cost, we use a threshold method").
//! * **Selective recomputation** — MemFine with the attention-recompute
//!   saving disabled, isolating how much of the M3-over-M1 edge comes
//!   from overlap vs recompute avoidance.
//! * **Capacity-factor baseline** — GShard-style drops: what fraction
//!   of routed copies a capacity factor must discard to match MemFine's
//!   memory, i.e. the accuracy price MemFine avoids.

use crate::config::{Method, ModelConfig, RunConfig};
use crate::router::baselines::apply_capacity_factor;
use crate::router::GatingSim;
use crate::sim::{RunOutcome, Simulator};
use crate::Result;

/// One bin-granularity ablation row.
#[derive(Clone, Debug)]
pub struct BinAblationRow {
    pub label: String,
    pub bins: Vec<u64>,
    pub peak_act_bytes: u64,
    pub avg_tgs: f64,
    pub oom_iterations: u64,
    /// Distinct chunk values used (= executables that must be compiled).
    pub distinct_chunks: usize,
}

/// Sweep MACT bin sets on the given run envelope.
pub fn bin_granularity(
    base: &RunConfig,
    bin_sets: &[(&str, Vec<u64>)],
) -> Result<Vec<BinAblationRow>> {
    let mut rows = Vec::new();
    for (label, bins) in bin_sets {
        let mut run = base.clone();
        run.method = Method::Mact(bins.clone());
        let out = Simulator::new(run)?.run_all();
        let mut used: Vec<u64> = out.chunks.records.iter().map(|r| r.chosen_c).collect();
        used.sort_unstable();
        used.dedup();
        rows.push(BinAblationRow {
            label: label.to_string(),
            bins: bins.clone(),
            peak_act_bytes: out.peak_act_bytes,
            avg_tgs: out.avg_tgs,
            oom_iterations: out.oom_iterations,
            distinct_chunks: used.len(),
        });
    }
    Ok(rows)
}

/// MACT with and without selective recomputation on the same trace,
/// isolating the recompute-avoidance share of the M3-over-M1 edge.
/// Returns (with_selective, without_selective) average TGS.
pub fn selective_recompute_effect(base: &RunConfig) -> Result<(f64, f64)> {
    let mut with = base.clone();
    with.method = Method::Mact(vec![1, 2, 4, 8]);
    let out_with = Simulator::new(with)?.run_all();

    let mut without = base.clone();
    without.method = Method::Mact(vec![1, 2, 4, 8]);
    without.allow_selective_recompute = false;
    let out_without = Simulator::new(without)?.run_all();
    Ok((out_with.avg_tgs, out_without.avg_tgs))
}

/// Drop fraction a GShard capacity factor would need to cap memory at
/// MemFine's chunked level on the hottest (iteration, layer).
#[derive(Clone, Debug)]
pub struct CapacityAblationRow {
    pub capacity_factor: f64,
    pub dropped_fraction: f64,
    pub peak_expert_tokens: u64,
}

pub fn capacity_factor_drops(
    model: &ModelConfig,
    run: &RunConfig,
    factors: &[f64],
) -> Vec<CapacityAblationRow> {
    let sim = GatingSim::new(model.clone(), run.parallel.clone(), run.seed);
    // hottest layer at the chaos peak
    let routing = sim.route(8, model.layers - 1);
    factors
        .iter()
        .map(|&cf| {
            let out = apply_capacity_factor(&routing.per_expert, cf);
            let total: u64 = routing.per_expert.iter().sum();
            CapacityAblationRow {
                capacity_factor: cf,
                dropped_fraction: out.dropped as f64 / total as f64,
                peak_expert_tokens: out.per_expert.iter().copied().max().unwrap_or(0),
            }
        })
        .collect()
}

/// Convenience: run one method end-to-end (used by the ablation bench).
pub fn run_method(base: &RunConfig, method: Method) -> Result<RunOutcome> {
    super::run_scenario(base, method, base.seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{model_i, paper_run};

    fn base() -> RunConfig {
        let mut r = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        r.iterations = 12;
        r
    }

    #[test]
    fn finer_bins_do_not_increase_memory() {
        let rows = bin_granularity(
            &base(),
            &[
                ("fine", vec![1, 2, 3, 4, 5, 6, 7, 8]),
                ("paper", vec![1, 2, 4, 8]),
                ("single-8", vec![8]),
            ],
        )
        .unwrap();
        // finer bins fit tighter → memory(fine) ≤ memory(paper);
        // single-8 over-chunks → lowest memory of all
        assert!(rows[0].peak_act_bytes <= rows[1].peak_act_bytes);
        assert!(rows[2].peak_act_bytes <= rows[0].peak_act_bytes);
        // but single-8 costs throughput
        assert!(rows[2].avg_tgs < rows[1].avg_tgs);
        // and the paper's bin set needs no more executables than bins
        assert!(rows[1].distinct_chunks <= 4);
        // nothing OOMs
        assert!(rows.iter().all(|r| r.oom_iterations == 0));
    }

    #[test]
    fn selective_recompute_is_a_real_win() {
        let (with, without) = selective_recompute_effect(&base()).unwrap();
        assert!(
            with > without,
            "selective recompute should gain TGS: {with} vs {without}"
        );
    }

    #[test]
    fn capacity_baseline_must_drop_heavily_at_peak() {
        let run = base();
        let rows = capacity_factor_drops(&run.model, &run, &[1.0, 2.0, 4.0]);
        // at the chaos peak, even cf=4 drops a meaningful share —
        // the accuracy price the paper's drop-free design refuses
        assert!(rows[0].dropped_fraction > rows[2].dropped_fraction);
        assert!(rows[0].dropped_fraction > 0.3, "{rows:?}");
        assert!(rows[2].dropped_fraction > 0.0, "{rows:?}");
    }
}
