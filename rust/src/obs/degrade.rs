//! The unified IO degradation ladder: bounded retry →
//! degrade-with-counter → quarantine.
//!
//! Every best-effort writer in the crate (the streaming checkpoint
//! record path, the trace store, the event log) used to improvise its
//! own failure shape; [`DegradeLadder`] replaces that with one
//! explicit, observable policy. An operation is retried in place up to
//! `retries` extra times; a failed operation degrades (the caller
//! keeps its in-memory result and a counter records the loss); after
//! `quarantine_after` *consecutive* degraded operations the ladder
//! quarantines itself and skips the writer entirely, so a dead disk
//! costs one syscall's worth of failures, not one per record.
//!
//! The ladder is deliberately sidecar-shaped: it never turns a failure
//! into a panic or an error for the caller — the caller decides what a
//! degraded write means (for checkpoints: the scenario stays
//! in-memory and is re-executed by merge catch-up, keeping campaign
//! artifacts byte-identical).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

use crate::error::Result;
use crate::logging;

/// What the ladder did with one operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LadderVerdict {
    /// The operation succeeded (possibly after retries).
    Ok,
    /// All attempts failed; the loss was counted.
    Degraded,
    /// This failure tripped the quarantine threshold — the ladder is
    /// now disabled and this is the transition report.
    Quarantined,
    /// The ladder was already quarantined; the operation was skipped
    /// without touching the writer.
    Skipped,
}

/// Thread-safe degradation ladder shared by all callers of one writer.
#[derive(Debug)]
pub struct DegradeLadder {
    site: &'static str,
    retries: u32,
    quarantine_after: u32,
    consecutive: AtomicU32,
    degraded: AtomicU64,
    quarantined: AtomicBool,
}

impl DegradeLadder {
    /// `retries` extra in-place attempts per operation;
    /// `quarantine_after` consecutive degraded operations disable the
    /// writer (0 = never quarantine).
    pub fn new(site: &'static str, retries: u32, quarantine_after: u32) -> Self {
        DegradeLadder {
            site,
            retries,
            quarantine_after,
            consecutive: AtomicU32::new(0),
            degraded: AtomicU64::new(0),
            quarantined: AtomicBool::new(false),
        }
    }

    /// Operations that ended degraded (all attempts failed).
    pub fn degraded(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Whether the writer has been quarantined.
    pub fn is_quarantined(&self) -> bool {
        self.quarantined.load(Ordering::Acquire)
    }

    /// Run one operation through the ladder.
    pub fn run<T>(&self, mut op: impl FnMut() -> Result<T>) -> (Option<T>, LadderVerdict) {
        if self.is_quarantined() {
            return (None, LadderVerdict::Skipped);
        }
        let mut last_err = None;
        for _ in 0..=self.retries {
            match op() {
                Ok(v) => {
                    self.consecutive.store(0, Ordering::Relaxed);
                    return (Some(v), LadderVerdict::Ok);
                }
                Err(e) => last_err = Some(e),
            }
        }
        self.degraded.fetch_add(1, Ordering::Relaxed);
        let consecutive = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        let err = last_err.expect("at least one attempt ran");
        if self.quarantine_after > 0
            && consecutive >= self.quarantine_after
            && !self.quarantined.swap(true, Ordering::AcqRel)
        {
            logging::warn(
                self.site,
                &format!(
                    "writer quarantined after {consecutive} consecutive degraded \
                     writes (last error: {err}); further writes are skipped"
                ),
            );
            return (None, LadderVerdict::Quarantined);
        }
        logging::warn(
            self.site,
            &format!("write degraded ({err}); result kept in memory only"),
        );
        (None, LadderVerdict::Degraded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    fn io_fail() -> Result<()> {
        Err(Error::Io(std::io::Error::from_raw_os_error(28)))
    }

    #[test]
    fn success_passes_through_and_resets_consecutive() {
        let ladder = DegradeLadder::new("test", 0, 2);
        let (v, verdict) = ladder.run(|| Ok(7u32));
        assert_eq!(v, Some(7));
        assert_eq!(verdict, LadderVerdict::Ok);
        assert_eq!(ladder.degraded(), 0);
        // one failure, then a success, then a failure: never 2 consecutive
        assert_eq!(ladder.run(io_fail).1, LadderVerdict::Degraded);
        assert_eq!(ladder.run(|| Ok(())).1, LadderVerdict::Ok);
        assert_eq!(ladder.run(io_fail).1, LadderVerdict::Degraded);
        assert!(!ladder.is_quarantined());
        assert_eq!(ladder.degraded(), 2);
    }

    #[test]
    fn bounded_retry_masks_transient_failures() {
        let ladder = DegradeLadder::new("test", 2, 2);
        let mut calls = 0;
        let (v, verdict) = ladder.run(|| {
            calls += 1;
            if calls < 3 {
                io_fail().map(|_| 0u32)
            } else {
                Ok(9)
            }
        });
        assert_eq!(calls, 3, "two retries after the first failure");
        assert_eq!(v, Some(9));
        assert_eq!(verdict, LadderVerdict::Ok);
        assert_eq!(ladder.degraded(), 0);
    }

    #[test]
    fn consecutive_failures_quarantine_then_skip() {
        let ladder = DegradeLadder::new("test", 0, 2);
        assert_eq!(ladder.run(io_fail).1, LadderVerdict::Degraded);
        assert_eq!(ladder.run(io_fail).1, LadderVerdict::Quarantined);
        assert!(ladder.is_quarantined());
        let mut called = false;
        let (_, verdict) = ladder.run(|| {
            called = true;
            Ok(())
        });
        assert_eq!(verdict, LadderVerdict::Skipped);
        assert!(!called, "quarantined ladder must not touch the writer");
        assert_eq!(ladder.degraded(), 2, "skips are not degrades");
    }

    #[test]
    fn zero_quarantine_threshold_never_quarantines() {
        let ladder = DegradeLadder::new("test", 0, 0);
        for _ in 0..10 {
            assert_eq!(ladder.run(io_fail).1, LadderVerdict::Degraded);
        }
        assert!(!ladder.is_quarantined());
        assert_eq!(ladder.degraded(), 10);
    }
}
