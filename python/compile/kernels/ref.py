"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package must match its oracle to float tolerance under pytest (see
python/tests/test_kernels.py). They are also used directly by the L2
model's backward pass (chunked recomputation recomputes through these
same formulas).

Shapes follow the grouped-expert layout used throughout MemFine:

  x        : (E, C, H)  tokens pre-gathered per local expert, padded to
                         the FCDA chunk capacity C
  w1, w3   : (E, H, G)  SwiGLU up/gate projections per expert
  w2       : (E, G, H)  down projection per expert
  mask     : (E, C)     1.0 for real tokens, 0.0 for padding slots

The FCDA chunk capacity C is the memory knob: drop-free routing means a
single expert may receive every token of the chunk, so C equals the
chunk's token count. Splitting a batch into c chunks divides C — and
with it the activation footprint — by c (paper Eq. 6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x: jnp.ndarray) -> jnp.ndarray:
    """SiLU / swish activation, x * sigmoid(x)."""
    return x * jax.nn.sigmoid(x)


def expert_ffn_ref(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Grouped SwiGLU expert FFN: w2 @ (silu(x@w1) * (x@w3)) per expert.

    Args:
      x:    (E, C, H) gathered tokens per expert.
      w1:   (E, H, G) gate projection.
      w3:   (E, H, G) up projection.
      w2:   (E, G, H) down projection.
      mask: optional (E, C); padded slots are zeroed in the output.

    Returns:
      (E, C, H) expert outputs.
    """
    gate = jnp.einsum("ech,ehg->ecg", x, w1)
    up = jnp.einsum("ech,ehg->ecg", x, w3)
    act = silu(gate) * up
    out = jnp.einsum("ecg,egh->ech", act, w2)
    if mask is not None:
        out = out * mask[..., None].astype(out.dtype)
    return out


def router_topk_ref(
    x: jnp.ndarray, w_gate: jnp.ndarray, top_k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-free top-k router: softmax gate, pick top_k experts per token.

    Ties are broken toward the lower expert index (matches the Pallas
    kernel's iterative argmax, and jnp.argmax semantics).

    Args:
      x:      (T, H) token activations.
      w_gate: (H, E) gating projection.
      top_k:  number of experts per token.

    Returns:
      weights: (T, top_k) renormalised routing weights (sum to 1).
      indices: (T, top_k) int32 expert ids, ordered by descending score.
    """
    logits = x @ w_gate  # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    idxs = []
    vals = []
    remaining = probs
    for _ in range(top_k):
        i = jnp.argmax(remaining, axis=-1)
        v = jnp.take_along_axis(remaining, i[:, None], axis=-1)[:, 0]
        idxs.append(i.astype(jnp.int32))
        vals.append(v)
        remaining = remaining.at[jnp.arange(remaining.shape[0]), i].set(-jnp.inf)
    indices = jnp.stack(idxs, axis=-1)
    weights = jnp.stack(vals, axis=-1)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights.astype(x.dtype), indices


def dispatch_ref(
    x: jnp.ndarray, indices: jnp.ndarray, n_experts: int, capacity: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather tokens into the (E, C, H) grouped layout (drop-free).

    Slot assignment is first-come-first-served in token order, matching
    the rust coordinator's dispatch planner. With capacity == T * top_k
    (worst case) nothing can overflow; smaller capacities surface as -1
    positions so tests can check drop-free-ness.

    Returns:
      gathered: (E, C, H)
      slot_mask: (E, C) 1.0 where a real token landed
      positions: (T, top_k) int32 flat slot id (e * C + slot), or -1 if
                 the token overflowed (only possible when C < demand).
    """
    t, h = x.shape
    top_k = indices.shape[1]

    def body(carry, tk):
        counts, gathered, slot_mask, positions = carry
        tok, k = tk // top_k, tk % top_k
        e = indices[tok, k]
        slot = counts[e]
        ok = slot < capacity
        pos = jnp.where(ok, e * capacity + slot, -1)
        slot_c = jnp.minimum(slot, capacity - 1)
        # Only write when the slot is fresh (ok); padding slots stay zero.
        contrib = jnp.where(ok, 1.0, 0.0).astype(x.dtype)
        gathered = gathered.at[e, slot_c].add(contrib * x[tok])
        slot_mask = slot_mask.at[e, slot_c].max(jnp.where(ok, 1.0, 0.0))
        counts = counts.at[e].add(jnp.where(ok, 1, 0))
        positions = positions.at[tok, k].set(pos)
        return (counts, gathered, slot_mask, positions), None

    counts0 = jnp.zeros((n_experts,), jnp.int32)
    gathered0 = jnp.zeros((n_experts, capacity, h), x.dtype)
    mask0 = jnp.zeros((n_experts, capacity), jnp.float32)
    pos0 = jnp.full((t, top_k), -1, jnp.int32)
    (counts, gathered, slot_mask, positions), _ = jax.lax.scan(
        body, (counts0, gathered0, mask0, pos0), jnp.arange(t * top_k)
    )
    del counts
    return gathered, slot_mask, positions


def combine_ref(
    expert_out: jnp.ndarray,
    positions: jnp.ndarray,
    weights: jnp.ndarray,
) -> jnp.ndarray:
    """Inverse of dispatch: weighted gather back to token order.

    Args:
      expert_out: (E, C, H) expert FFN outputs.
      positions:  (T, top_k) flat slot ids from dispatch_ref (-1 = dropped).
      weights:    (T, top_k) routing weights.

    Returns:
      (T, H) combined output.
    """
    e, c, h = expert_out.shape
    flat = expert_out.reshape(e * c, h)
    safe_pos = jnp.maximum(positions, 0)
    picked = flat[safe_pos]  # (T, top_k, H)
    valid = (positions >= 0).astype(picked.dtype)[..., None]
    w = weights[..., None].astype(picked.dtype)
    return jnp.sum(picked * w * valid, axis=1)


def moe_layer_ref(
    x: jnp.ndarray,
    w_gate: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    top_k: int,
    capacity: int | None = None,
) -> jnp.ndarray:
    """Full drop-free MoE layer on a flat token batch: route, dispatch,
    expert FFN, combine. Capacity defaults to the drop-free worst case
    (every routed copy lands on one expert)."""
    t = x.shape[0]
    n_experts = w_gate.shape[1]
    if capacity is None:
        capacity = t * top_k
    weights, indices = router_topk_ref(x, w_gate, top_k)
    gathered, slot_mask, positions = dispatch_ref(x, indices, n_experts, capacity)
    out = expert_ffn_ref(gathered, w1, w3, w2, slot_mask)
    return combine_ref(out, positions, weights)


def moe_layer_chunked_ref(
    x: jnp.ndarray,
    w_gate: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    top_k: int,
    n_chunks: int,
) -> jnp.ndarray:
    """FCDA forward (paper Eq. 6): split tokens into n_chunks, run
    dispatch-compute-combine per chunk, concat. Must equal moe_layer_ref
    exactly (routing is per-token, so chunking is semantically invisible)
    — this equivalence is a pytest invariant."""
    t = x.shape[0]
    assert t % n_chunks == 0, "chunk split must be exact"
    outs = [
        moe_layer_ref(xc, w_gate, w1, w3, w2, top_k)
        for xc in jnp.split(x, n_chunks, axis=0)
    ]
    return jnp.concatenate(outs, axis=0)
