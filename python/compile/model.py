"""Layer-2: the MoE transformer in JAX (build-time only).

Defines the model whose train step is AOT-lowered to HLO text and driven
from the rust coordinator (examples/train_moe.rs). The MoE layer uses the
Layer-1 Pallas kernels through `expert_ffn_ad`, whose custom VJP performs
the paper's *chunked recomputation* (Eq. 7): forward stores only chunk
inputs, backward re-runs the expert math per chunk.

FCDA appears here as `n_chunks`: the flat token batch is split into
n_chunks chunks and each chunk flows through router→dispatch→expert→
combine independently (Eq. 6). Chunked and unchunked forward are
identical in exact arithmetic (routing is per-token) — pytest checks
this equivalence to float tolerance.

For differentiability the training path evaluates experts densely
(every token through every expert, combined with the sparse router
weights, zero weight ⇒ zero contribution — numerically identical to
sparse dispatch). The *sparse* dispatch path lives in the rust
coordinator, which is the component the paper actually contributes; the
rust side drives the same per-chunk expert kernel artifact.

Parameters travel as ONE flat f32 vector across the rust boundary, so
the train-step executable has a tiny, stable signature:
    (params, m, v, tokens, step) -> (params', m', v', loss)
The slice layout is recorded in artifacts/manifest.json.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.expert_ffn import expert_ffn_ad
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Mini-DeepSeek-style MoE transformer configuration.

    Mirrors the paper's Table 1 notation where applicable: L layers of
    which the first `n_dense_layers` use a dense FFN (paper's d_l), the
    rest MoE with `n_experts` experts, top_k routing, expert intermediate
    size g_e = d_ff.
    """

    vocab: int = 8192
    seq: int = 128
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 4
    n_dense_layers: int = 1
    n_experts: int = 8
    top_k: int = 2
    d_ff: int = 512  # expert intermediate (g_e)
    d_ff_dense: int = 1024  # dense-layer intermediate (g_d)
    batch: int = 4
    n_chunks: int = 2  # FCDA chunk count used in the exported train step

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def tokens_per_batch(self) -> int:
        return self.batch * self.seq


TINY = ModelConfig(
    vocab=512, seq=32, d_model=64, n_heads=2, n_layers=2, n_dense_layers=1,
    n_experts=4, top_k=2, d_ff=128, d_ff_dense=256, batch=2, n_chunks=2,
)

# The E2E config for examples/train_moe.rs: ~20M params. (The brief asks
# ~100M; this box has a single CPU core — documented in EXPERIMENTS.md.)
E2E = ModelConfig()


# ---------------------------------------------------------------------------
# Parameter pytree <-> flat vector
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) table — the single source of truth for the
    flat-vector layout shared with rust via manifest.json."""
    shapes: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos_embed", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes += [
            (p + "ln1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2", (cfg.d_model,)),
        ]
        if i < cfg.n_dense_layers:
            shapes += [
                (p + "ffn_w1", (cfg.d_model, cfg.d_ff_dense)),
                (p + "ffn_w3", (cfg.d_model, cfg.d_ff_dense)),
                (p + "ffn_w2", (cfg.d_ff_dense, cfg.d_model)),
            ]
        else:
            shapes += [
                (p + "gate", (cfg.d_model, cfg.n_experts)),
                (p + "moe_w1", (cfg.n_experts, cfg.d_model, cfg.d_ff)),
                (p + "moe_w3", (cfg.n_experts, cfg.d_model, cfg.d_ff)),
                (p + "moe_w2", (cfg.n_experts, cfg.d_ff, cfg.d_model)),
            ]
    shapes += [
        ("ln_f", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return shapes


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_shapes(cfg):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


def unflatten(cfg: ModelConfig, vec: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """Slice the flat parameter vector back into the named pytree."""
    params = {}
    off = 0
    for name, shape in param_shapes(cfg):
        n = 1
        for d in shape:
            n *= d
        params[name] = vec[off : off + n].reshape(shape)
        off += n
    assert off == vec.shape[0], (off, vec.shape)
    return params


def flatten(cfg: ModelConfig, params: dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_shapes(cfg)]
    )


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jnp.ndarray]:
    """Scaled-normal init; norm gains start at 1."""
    params = {}
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            params[name] = (jax.random.normal(sub, shape) * scale).astype(
                jnp.float32
            )
    return params


# ---------------------------------------------------------------------------
# Model blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def attention(p: dict[str, jnp.ndarray], prefix: str, x: jnp.ndarray,
              n_heads: int) -> jnp.ndarray:
    """Causal multi-head attention over (B, S, D)."""
    b, s, d = x.shape
    hd = d // n_heads

    def proj(w):
        return (x @ w).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    q = proj(p[prefix + "wq"])
    k = proj(p[prefix + "wk"])
    v = proj(p[prefix + "wv"])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ p[prefix + "wo"]


def dense_ffn(p: dict[str, jnp.ndarray], prefix: str, x: jnp.ndarray) -> jnp.ndarray:
    gate = x @ p[prefix + "ffn_w1"]
    up = x @ p[prefix + "ffn_w3"]
    return (ref.silu(gate) * up) @ p[prefix + "ffn_w2"]


def moe_ffn_chunk(p: dict[str, jnp.ndarray], prefix: str, xc: jnp.ndarray,
                  cfg: ModelConfig) -> jnp.ndarray:
    """One FCDA chunk through the MoE layer (dense differentiable eval).

    xc: (Tc, D) chunk of flat tokens. Every token is evaluated by every
    expert via the Pallas kernel (chunked-recompute VJP) and combined
    with the sparse top-k router weights — numerically identical to
    sparse drop-free dispatch.
    """
    tc, d = xc.shape
    e = cfg.n_experts
    weights, indices = ref.router_topk_ref(xc, p[prefix + "gate"], cfg.top_k)
    # Dense (T, E) combine matrix from the sparse top-k selection.
    onehot = jax.nn.one_hot(indices, e, dtype=xc.dtype)  # (Tc, K, E)
    w_dense = jnp.einsum("tk,tke->te", weights, onehot)  # (Tc, E)
    # Every expert sees the full chunk: (E, Tc, D).
    x_tiled = jnp.broadcast_to(xc[None], (e, tc, d))
    mask = jnp.ones((e, tc), jnp.float32)
    out = expert_ffn_ad(
        x_tiled, p[prefix + "moe_w1"], p[prefix + "moe_w3"],
        p[prefix + "moe_w2"], mask,
    )  # (E, Tc, D)
    return jnp.einsum("etd,te->td", out, w_dense)


def moe_ffn(p: dict[str, jnp.ndarray], prefix: str, x: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """FCDA forward over the flat token batch (paper Eq. 6): split into
    cfg.n_chunks chunks, process each sequentially, concatenate."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    n_chunks = cfg.n_chunks
    assert (b * s) % n_chunks == 0
    outs = [
        moe_ffn_chunk(p, prefix, xc, cfg)
        for xc in jnp.split(flat, n_chunks, axis=0)
    ]
    return jnp.concatenate(outs, axis=0).reshape(b, s, d)


def forward(cfg: ModelConfig, p: dict[str, jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Logits for next-token prediction. tokens: (B, S) int32."""
    x = p["embed"][tokens] + p["pos_embed"][None, :, :]
    for i in range(cfg.n_layers):
        pref = f"layer{i}."
        x = x + attention(p, pref, rmsnorm(x, p[pref + "ln1"]), cfg.n_heads)
        h = rmsnorm(x, p[pref + "ln2"])
        if i < cfg.n_dense_layers:
            x = x + dense_ffn(p, pref, h)
        else:
            x = x + moe_ffn(p, pref, h, cfg)
    x = rmsnorm(x, p["ln_f"])
    return x @ p["head"]


def loss_fn(cfg: ModelConfig, p: dict[str, jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy over (B, S-1) positions."""
    logits = forward(cfg, p, tokens)  # (B, S, V)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Train step (Adam) over the flat parameter vector
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def train_step(cfg: ModelConfig, params: jnp.ndarray, m: jnp.ndarray,
               v: jnp.ndarray, tokens: jnp.ndarray, step: jnp.ndarray,
               lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8) -> tuple[jnp.ndarray, ...]:
    """One Adam step. All state is flat f32; `step` is a float scalar
    (1-based) used for bias correction. Returns (params', m', v', loss).

    Gradients are taken w.r.t. the *pytree* and flattened afterwards:
    differentiating through the unflatten slices makes XLA build a
    scatter-shaped cotangent per slice and runs ~3× slower (measured
    4.1 s vs 1.4 s per step on the E2E config — EXPERIMENTS.md §Perf).
    """
    tree = unflatten(cfg, params)
    loss, grad_tree = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens)
    )(tree)
    grad = flatten(cfg, grad_tree)
    m2 = b1 * m + (1 - b1) * grad
    v2 = b2 * v + (1 - b2) * jnp.square(grad)
    mhat = m2 / (1 - b1**step)
    vhat = v2 / (1 - b2**step)
    new_params = params - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_params, m2, v2, loss


def eval_loss(cfg: ModelConfig, params: jnp.ndarray,
              tokens: jnp.ndarray) -> jnp.ndarray:
    """Loss without update (exported as the fwd_loss artifact)."""
    return loss_fn(cfg, unflatten(cfg, params), tokens)
