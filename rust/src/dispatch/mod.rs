//! All-to-all dispatch planning: the token movement of Eq. 4's
//! `dispatch(X)` / `combine(...)`, planned by the Layer-3 coordinator.
//!
//! Given per-token expert assignments on each source rank, the planner
//! builds (a) the per-(src, expert) send counts that drive the
//! all-to-all, (b) the slot placement of every token copy in the
//! destination rank's grouped `(local_expert, capacity)` buffer, and
//! (c) the inverse permutation used by combine. The real-execution
//! coordinator moves actual `f32` rows with this plan; the simulator
//! only uses the counts.
//!
//! Invariants (property-tested here and mirrored in python ref.py):
//!   * conservation: every routed copy lands in exactly one slot or is
//!     counted as overflow (overflow = 0 when capacity is drop-free);
//!   * combine ∘ dispatch = identity on token ids;
//!   * slot ids are unique per destination buffer.

use crate::config::ParallelConfig;
use crate::error::{Error, Result};

/// A token copy's route: source rank, token index, k-th choice, expert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    pub src_rank: u32,
    pub token: u32,
    pub k: u8,
    pub expert: u32,
}

/// Placement of one token copy in a destination buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub route: Route,
    /// Destination EP rank (owner of the expert).
    pub dst_rank: u32,
    /// Local expert index on the destination rank.
    pub local_expert: u32,
    /// Slot within the expert's capacity region, or `None` if the copy
    /// overflowed a non-drop-free capacity.
    pub slot: Option<u32>,
}

/// The computed all-to-all plan for one chunk of tokens.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    /// Experts per rank.
    pub experts_per_rank: u32,
    /// Per-expert capacity of the destination buffers.
    pub capacity: u32,
    /// send_counts[src][dst] = token copies moving src → dst.
    pub send_counts: Vec<Vec<u64>>,
    /// Every copy's placement, in (src_rank, token, k) order.
    pub placements: Vec<Placement>,
    /// Copies that exceeded capacity (0 under drop-free sizing).
    pub overflow: u64,
}

impl DispatchPlan {
    /// Received copies per destination rank (the `s''` vector).
    pub fn received_per_rank(&self) -> Vec<u64> {
        let ranks = self.send_counts.len();
        let mut recv = vec![0u64; ranks];
        for src in &self.send_counts {
            for (dst, &c) in src.iter().enumerate() {
                recv[dst] += c;
            }
        }
        recv
    }

    /// Total placed (non-overflow) copies.
    pub fn placed(&self) -> u64 {
        self.placements.iter().filter(|p| p.slot.is_some()).count() as u64
    }
}

/// Expert owner under block layout (rank k hosts experts
/// [k·per, (k+1)·per)).
pub fn owner_of(expert: u32, experts_per_rank: u32) -> u32 {
    expert / experts_per_rank
}

/// Build the all-to-all plan for one chunk.
///
/// `assignments[src][token]` lists the top-k expert choices of that
/// token. `capacity` is the per-(rank, local expert) buffer size; pass
/// [`drop_free_capacity`] for the paper's unrestricted routing.
pub fn plan(
    parallel: &ParallelConfig,
    n_experts: u32,
    assignments: &[Vec<Vec<u32>>],
    capacity: u32,
) -> Result<DispatchPlan> {
    let ranks = parallel.ep as usize;
    if assignments.len() != ranks {
        return Err(Error::schedule(format!(
            "assignments for {} ranks, expected ep={}",
            assignments.len(),
            ranks
        )));
    }
    if n_experts % parallel.ep as u32 != 0 {
        return Err(Error::schedule("experts not divisible by ep"));
    }
    let experts_per_rank = n_experts / parallel.ep as u32;
    let mut send_counts = vec![vec![0u64; ranks]; ranks];
    // next free slot per expert, flat-indexed — one cache line per few
    // experts instead of a Vec<Vec> indirection in the inner loop.
    let mut next_slot = vec![0u32; n_experts as usize];
    let total_copies: usize = assignments
        .iter()
        .map(|r| r.iter().map(Vec::len).sum::<usize>())
        .sum();
    let mut placements = Vec::with_capacity(total_copies);
    let mut overflow = 0u64;

    for (src, tokens) in assignments.iter().enumerate() {
        for (tok, choices) in tokens.iter().enumerate() {
            for (k, &expert) in choices.iter().enumerate() {
                if expert >= n_experts {
                    return Err(Error::schedule(format!(
                        "expert {expert} out of range (n={n_experts})"
                    )));
                }
                let dst = owner_of(expert, experts_per_rank);
                let local = expert % experts_per_rank;
                send_counts[src][dst as usize] += 1;
                let slot_ref = &mut next_slot[expert as usize];
                let slot = if *slot_ref < capacity {
                    let s = *slot_ref;
                    *slot_ref += 1;
                    Some(s)
                } else {
                    overflow += 1;
                    None
                };
                placements.push(Placement {
                    route: Route {
                        src_rank: src as u32,
                        token: tok as u32,
                        k: k as u8,
                        expert,
                    },
                    dst_rank: dst,
                    local_expert: local,
                    slot,
                });
            }
        }
    }
    Ok(DispatchPlan {
        experts_per_rank,
        capacity,
        send_counts,
        placements,
        overflow,
    })
}

/// Drop-free capacity for a chunk of `chunk_tokens` tokens with top-k
/// routing: in the worst case every copy of every token in the chunk
/// (from all `ep` source ranks) lands on ONE expert.
pub fn drop_free_capacity(chunk_tokens: u32, top_k: u32, ep: u32) -> u32 {
    chunk_tokens * top_k * ep
}

/// Combine: given per-copy outputs keyed by placement, accumulate the
/// weighted sum back per (src_rank, token). Returns
/// `out[src][token] = Σ_k weight · value` for scalar values — the
/// coordinator uses the same traversal for full hidden vectors.
pub fn combine_scalar(
    plan: &DispatchPlan,
    n_tokens_per_rank: &[usize],
    value_of: impl Fn(&Placement) -> f64,
    weight_of: impl Fn(&Route) -> f64,
) -> Vec<Vec<f64>> {
    let mut out: Vec<Vec<f64>> = n_tokens_per_rank
        .iter()
        .map(|&n| vec![0.0; n])
        .collect();
    for p in &plan.placements {
        if p.slot.is_some() {
            out[p.route.src_rank as usize][p.route.token as usize] +=
                weight_of(&p.route) * value_of(p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_parallel;

    fn small_parallel() -> ParallelConfig {
        let mut p = paper_parallel();
        p.ep = 4;
        p
    }

    /// 4 ranks × 3 tokens, top-2, 8 experts (2 per rank).
    fn assignments() -> Vec<Vec<Vec<u32>>> {
        vec![
            vec![vec![0, 1], vec![2, 3], vec![4, 5]],
            vec![vec![6, 7], vec![0, 2], vec![4, 6]],
            vec![vec![1, 3], vec![5, 7], vec![0, 4]],
            vec![vec![2, 6], vec![3, 5], vec![1, 7]],
        ]
    }

    #[test]
    fn conservation_total_copies() {
        let p = small_parallel();
        let plan = plan(&p, 8, &assignments(), 64).unwrap();
        assert_eq!(plan.placements.len(), 4 * 3 * 2);
        assert_eq!(plan.overflow, 0);
        assert_eq!(plan.placed(), 24);
        let total_sent: u64 = plan.send_counts.iter().flatten().sum();
        assert_eq!(total_sent, 24);
    }

    #[test]
    fn received_matches_send_matrix() {
        let p = small_parallel();
        let plan = plan(&p, 8, &assignments(), 64).unwrap();
        let recv = plan.received_per_rank();
        assert_eq!(recv.iter().sum::<u64>(), 24);
        // every expert appears exactly 3 times in assignments()
        assert_eq!(recv, vec![6, 6, 6, 6]);
    }

    #[test]
    fn slots_unique_per_buffer() {
        let p = small_parallel();
        let plan = plan(&p, 8, &assignments(), 64).unwrap();
        let mut seen = std::collections::HashSet::new();
        for pl in &plan.placements {
            if let Some(slot) = pl.slot {
                assert!(seen.insert((pl.dst_rank, pl.local_expert, slot)));
            }
        }
    }

    #[test]
    fn overflow_when_capacity_small() {
        let p = small_parallel();
        // capacity 1 but each expert receives 3 copies → 2 overflow each
        let plan = plan(&p, 8, &assignments(), 1).unwrap();
        assert_eq!(plan.overflow, 8 * 2);
        assert_eq!(plan.placed(), 8);
    }

    #[test]
    fn drop_free_capacity_never_overflows() {
        let p = small_parallel();
        let cap = drop_free_capacity(3, 2, 4);
        let plan = plan(&p, 8, &assignments(), cap).unwrap();
        assert_eq!(plan.overflow, 0);
    }

    #[test]
    fn combine_roundtrip_identity() {
        // With value(placement) = src·100 + token and top-1 weight 1.0,
        // combine must reproduce each token's own id.
        let p = small_parallel();
        let top1: Vec<Vec<Vec<u32>>> = assignments()
            .iter()
            .map(|r| r.iter().map(|t| vec![t[0]]).collect())
            .collect();
        let plan = plan(&p, 8, &top1, 64).unwrap();
        let out = combine_scalar(
            &plan,
            &[3, 3, 3, 3],
            |pl| (pl.route.src_rank * 100 + pl.route.token) as f64,
            |_| 1.0,
        );
        for (src, tokens) in out.iter().enumerate() {
            for (tok, &v) in tokens.iter().enumerate() {
                assert_eq!(v, (src * 100 + tok) as f64);
            }
        }
    }

    #[test]
    fn combine_weights_sum() {
        // top-2 with weights 0.5/0.5 over identical values = the value.
        let p = small_parallel();
        let plan = plan(&p, 8, &assignments(), 64).unwrap();
        let out = combine_scalar(&plan, &[3, 3, 3, 3], |_| 2.0, |_| 0.5);
        for tokens in out {
            for v in tokens {
                assert!((v - 2.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn owner_block_layout() {
        assert_eq!(owner_of(0, 2), 0);
        assert_eq!(owner_of(1, 2), 0);
        assert_eq!(owner_of(2, 2), 1);
        assert_eq!(owner_of(7, 2), 3);
    }

    #[test]
    fn rejects_bad_expert_id() {
        let p = small_parallel();
        let bad = vec![vec![vec![99u32]], vec![], vec![], vec![]];
        assert!(plan(&p, 8, &bad, 4).is_err());
    }

    #[test]
    fn rejects_rank_mismatch() {
        let p = small_parallel();
        assert!(plan(&p, 8, &[vec![]], 4).is_err());
    }
}
