"""Layer-1 Pallas kernel: drop-free top-k softmax router.

The gating network of the MoE layer: per token, softmax over expert
logits, then iterative argmax selection of the top-k experts with
renormalised weights. Unrestricted (no capacity factor) — the whole
point of MemFine is to keep routing drop-free and tame memory elsewhere.

Grid: one step per token tile. The (H, E) gating matrix is small enough
to live in VMEM for every step; the iterative top-k loop is unrolled
k times (k ≤ 8 in all paper configs).

interpret=True for the CPU PJRT path, as everywhere in this repo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TOKEN_TILE = 32


def _router_kernel(top_k, x_ref, wg_ref, w_ref, i_ref):
    """One token-tile grid step.

    x_ref:  (Tc, H) token tile
    wg_ref: (H, E) gating matrix
    w_ref:  (Tc, K) out: renormalised top-k weights
    i_ref:  (Tc, K) out: int32 expert indices
    """
    x = x_ref[...]
    wg = wg_ref[...]
    logits = jnp.dot(x, wg, preferred_element_type=jnp.float32)  # (Tc, E)
    # Numerically-stable softmax on the tile.
    m = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)

    tc, e = probs.shape
    remaining = probs
    idxs = []
    vals = []
    col = jax.lax.broadcasted_iota(jnp.int32, (tc, e), 1)
    for _ in range(top_k):
        i = jnp.argmax(remaining, axis=-1).astype(jnp.int32)  # (Tc,)
        v = jnp.max(remaining, axis=-1)
        idxs.append(i)
        vals.append(v)
        hit = col == i[:, None]
        remaining = jnp.where(hit, -jnp.inf, remaining)
    indices = jnp.stack(idxs, axis=-1)  # (Tc, K)
    weights = jnp.stack(vals, axis=-1)  # (Tc, K)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    w_ref[...] = weights.astype(w_ref.dtype)
    i_ref[...] = indices


@functools.partial(jax.jit, static_argnames=("top_k", "token_tile"))
def router_topk(
    x: jnp.ndarray,
    w_gate: jnp.ndarray,
    top_k: int,
    token_tile: int = DEFAULT_TOKEN_TILE,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas drop-free top-k router.

    Args:
      x:      (T, H) token activations; T must be divisible by token_tile.
      w_gate: (H, E) gating projection.
      top_k:  experts per token (static).

    Returns:
      (weights (T, top_k), indices (T, top_k) int32); matches
      ref.router_topk_ref (pytest invariant, ties → lower index).
    """
    t, h = x.shape
    e = w_gate.shape[1]
    if t % token_tile != 0:
        raise ValueError(f"token count {t} not divisible by tile {token_tile}")
    grid = (t // token_tile,)
    kernel = functools.partial(_router_kernel, top_k)
    weights, indices = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, h), lambda ti: (ti, 0)),
            pl.BlockSpec((h, e), lambda ti: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((token_tile, top_k), lambda ti: (ti, 0)),
            pl.BlockSpec((token_tile, top_k), lambda ti: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, top_k), x.dtype),
            jax.ShapeDtypeStruct((t, top_k), jnp.int32),
        ],
        interpret=True,
    )(x, w_gate)
    return weights, indices
