//! Trace recording: routing distributions, MACT decisions and memory
//! peaks per (iteration, layer), with CSV/JSON export and replay.
//!
//! Fig. 2 is one iteration's slice of a [`RoutingTrace`]; Fig. 5 is a
//! [`ChunkTrace`] rendered layer × iteration. Benches write these next
//! to their stdout tables so plots can be regenerated offline.
//!
//! [`SharedRoutingTrace`] is the execution-side counterpart: the
//! routed-token stream of one (model, gating, seed) cell, drawn *once*
//! and evaluated by every method — the paper's paired-comparison
//! structure (Methods 1/2/3 on identical token streams) made
//! first-class, and the sweep engine's main throughput lever.
//!
//! [`provenance`] records *which sampler and RNG version* drew a
//! stream (baked into scenario hashes, checkpoint headers and report
//! metadata), and [`store`] caches drawn traces on disk keyed by that
//! full identity, so re-sweeps of the same (model, seed) cells skip
//! generation entirely.

pub mod provenance;
pub mod store;

pub use provenance::{RngVersion, RouterSampler, TraceProvenance};
pub use store::{trace_key, TraceStore};

use crate::json::{self, Value};
use crate::metrics::CsvWriter;
use crate::router::GatingSim;
use crate::Result;

/// Per-(iteration, layer) routing statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct RoutingRecord {
    pub iteration: u64,
    pub layer: u64,
    pub min_recv: u64,
    pub mean_recv: f64,
    pub max_recv: u64,
}

/// Full routing trace of a run.
#[derive(Clone, Debug, Default)]
pub struct RoutingTrace {
    pub records: Vec<RoutingRecord>,
}

impl RoutingTrace {
    /// Append a record. Records must arrive in ascending iteration
    /// order (the simulator emits them iteration-major) — the
    /// invariant [`RoutingTrace::iteration`]'s binary search relies
    /// on, checked O(1) here against the previous record.
    pub fn push(&mut self, r: RoutingRecord) {
        debug_assert!(
            self.records.last().map_or(true, |prev| prev.iteration <= r.iteration),
            "RoutingTrace records must be pushed in ascending iteration order"
        );
        self.records.push(r);
    }

    /// All records of one iteration (a Fig. 2 slice), as a sub-slice.
    ///
    /// Records are pushed in ascending-iteration order (enforced by
    /// [`RoutingTrace::push`]), so the range is found by binary
    /// search — walking every iteration of a trace is O(iterations ·
    /// log records) instead of the old O(records × iterations) full
    /// re-filter per call.
    pub fn iteration(&self, it: u64) -> &[RoutingRecord] {
        let start = self.records.partition_point(|r| r.iteration < it);
        let end = self.records.partition_point(|r| r.iteration <= it);
        &self.records[start..end]
    }

    /// Peak received tokens over the whole trace (drives Table 4's
    /// worst-case activation column).
    pub fn peak_recv(&self) -> u64 {
        self.records.iter().map(|r| r.max_recv).max().unwrap_or(0)
    }

    pub fn to_csv(&self) -> Result<String> {
        let mut w = CsvWriter::new(
            Vec::new(),
            &["iteration", "layer", "min_recv", "mean_recv", "max_recv"],
        )?;
        for r in &self.records {
            w.row(&[
                r.iteration.to_string(),
                r.layer.to_string(),
                r.min_recv.to_string(),
                format!("{:.1}", r.mean_recv),
                r.max_recv.to_string(),
            ])?;
        }
        Ok(String::from_utf8(w.into_inner()).expect("csv is utf8"))
    }
}

/// The routed-token stream of one (model, gating, seed) cell, drawn
/// once per cell and shared by every method evaluated against it.
///
/// MemFine's comparison is *paired by construction*: Methods 1/2/3
/// differ only in how they chunk/recompute, never in where tokens
/// land, so the routing statistics per (iteration, MoE layer) are
/// method-independent. Historically each `run_scenario` re-drew the
/// full multinomial trace; generating it once here removes the
/// dominant per-scenario cost from all but the first method of a cell.
///
/// Determinism: [`GatingSim::route`] forks a fresh RNG stream from
/// `(seed, iteration, layer)` for every draw, so the records here are
/// bit-identical to what per-method drawing produced — trace sharing
/// changes *when* the stream is drawn, never *what* is drawn. The
/// records are stored iteration-major, MoE-layer-minor, matching the
/// order the simulator historically drew them in.
#[derive(Clone, Debug)]
pub struct SharedRoutingTrace {
    /// The routing seed the trace was drawn from (becomes the
    /// scenario seed of every method evaluated against it).
    pub seed: u64,
    /// Iterations covered (methods may simulate fewer, never more).
    pub iterations: u64,
    /// The model the trace was drawn for. Part of the trace's
    /// identity: the records are meaningless against any other model.
    pub model: crate::config::ModelConfig,
    /// The parallelism layout the per-rank statistics were computed
    /// under (EP width shapes `min_recv`/`max_recv`) — identity too.
    pub parallel: crate::config::ParallelConfig,
    /// First iteration covered. 0 for whole-cell traces (the only kind
    /// the on-disk store holds); a range trace from
    /// [`SharedRoutingTrace::generate_range`] starts here and covers
    /// `[first_iteration, iterations)`. Because every draw stream
    /// forks statelessly per (iteration, layer), a range trace's
    /// records are bit-identical to the same rows of the full trace.
    pub first_iteration: u64,
    /// One record per covered (iteration, MoE layer), iteration-major.
    pub records: Vec<RoutingRecord>,
}

impl SharedRoutingTrace {
    /// Draw the full trace for `iterations` iterations of the job
    /// `gating` describes. The per-(iteration, layer) statistics are
    /// exactly what [`GatingSim::route`] + `summary()` produce.
    pub fn generate(gating: &GatingSim, iterations: u64) -> Self {
        Self::generate_range(gating, 0, iterations)
    }

    /// Draw only iterations `[lo, hi)` of the trace — the intra-cell
    /// split path. `route_stats` forks a fresh stream per (iteration,
    /// layer), so the records here are bit-identical to the same rows
    /// of [`SharedRoutingTrace::generate`]`(gating, hi)`: concatenating
    /// adjacent range traces reproduces the full trace exactly, at any
    /// split boundary, under either rng version.
    pub fn generate_range(gating: &GatingSim, lo: u64, hi: u64) -> Self {
        assert!(lo <= hi, "trace range {lo}..{hi} is inverted");
        let layers = gating.model.layers;
        let dense_layers = gating.model.dense_layers;
        let moe = (layers - dense_layers) as usize;
        let mut records = Vec::with_capacity(moe * (hi - lo) as usize);
        // One set of probability/count buffers serves every draw of the
        // trace ([`GatingSim::route_stats`] is pinned bit-identical to
        // the allocating `route()` path).
        let mut scratch = crate::router::RouteScratch::new(&gating.model, &gating.parallel);
        for iteration in lo..hi {
            for layer in dense_layers..layers {
                let (min_recv, mean_recv, max_recv) =
                    gating.route_stats(iteration, layer, &mut scratch);
                records.push(RoutingRecord {
                    iteration,
                    layer,
                    min_recv,
                    mean_recv,
                    max_recv,
                });
            }
        }
        SharedRoutingTrace {
            seed: gating.seed(),
            iterations: hi,
            model: gating.model.clone(),
            parallel: gating.parallel.clone(),
            first_iteration: lo,
            records,
        }
    }

    /// MoE layers per iteration (the stride of `records`).
    pub fn moe_layers(&self) -> usize {
        (self.model.layers - self.model.dense_layers) as usize
    }

    /// The records of one iteration, ordered by ascending MoE layer.
    /// `it` is the absolute iteration number; a range trace indexes
    /// relative to its `first_iteration`.
    pub fn iteration(&self, it: u64) -> &[RoutingRecord] {
        debug_assert!(
            it >= self.first_iteration && it < self.iterations,
            "iteration {it} outside trace range {}..{}",
            self.first_iteration,
            self.iterations
        );
        let stride = self.moe_layers();
        let start = (it - self.first_iteration) as usize * stride;
        &self.records[start..start + stride]
    }

    /// Peak received tokens anywhere in the trace.
    pub fn peak_recv(&self) -> u64 {
        self.records.iter().map(|r| r.max_recv).max().unwrap_or(0)
    }
}

/// Per-(iteration, layer) MACT decision (Fig. 5 cell).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkRecord {
    pub iteration: u64,
    pub layer: u64,
    pub chosen_c: u64,
}

/// The Fig. 5 trace.
#[derive(Clone, Debug, Default)]
pub struct ChunkTrace {
    pub records: Vec<ChunkRecord>,
}

impl ChunkTrace {
    pub fn push(&mut self, r: ChunkRecord) {
        self.records.push(r);
    }

    /// Render the layer × iteration grid as rows of chunk values
    /// (layers ascending; one column per iteration).
    pub fn grid(&self, layers: u64, iterations: u64) -> Vec<Vec<u64>> {
        let mut g = vec![vec![0u64; iterations as usize]; layers as usize];
        for r in &self.records {
            if r.layer < layers && r.iteration < iterations {
                g[r.layer as usize][r.iteration as usize] = r.chosen_c;
            }
        }
        g
    }

    /// Mean chunk value per iteration — the "first increases then
    /// decreases" trend the paper reads off Fig. 5. One pass over the
    /// records into per-iteration accumulators (the old implementation
    /// re-filtered the whole record list per iteration and collected a
    /// throwaway `Vec<f64>` each time — O(records × iterations));
    /// per-iteration sums still accumulate in record order, so the
    /// emitted floats are unchanged.
    pub fn mean_per_iteration(&self, iterations: u64) -> Vec<f64> {
        let mut sums = vec![0.0f64; iterations as usize];
        let mut counts = vec![0u64; iterations as usize];
        for r in &self.records {
            if r.iteration < iterations {
                sums[r.iteration as usize] += r.chosen_c as f64;
                counts[r.iteration as usize] += 1;
            }
        }
        sums.into_iter()
            .zip(counts)
            .map(|(sum, n)| if n == 0 { 0.0 } else { sum / n as f64 })
            .collect()
    }

    pub fn to_json(&self) -> Value {
        json::arr(
            self.records
                .iter()
                .map(|r| {
                    json::obj(vec![
                        ("iteration", json::num(r.iteration as f64)),
                        ("layer", json::num(r.layer as f64)),
                        ("chunk", json::num(r.chosen_c as f64)),
                    ])
                })
                .collect(),
        )
    }

    /// Parse back from the JSON written by `to_json` (replay support).
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut t = ChunkTrace::default();
        for item in v.as_arr().unwrap_or(&[]) {
            t.push(ChunkRecord {
                iteration: item.req_u64("iteration")?,
                layer: item.req_u64("layer")?,
                chosen_c: item.req_u64("chunk")?,
            });
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_trace_queries() {
        let mut t = RoutingTrace::default();
        t.push(RoutingRecord { iteration: 0, layer: 0, min_recv: 1, mean_recv: 2.0, max_recv: 3 });
        t.push(RoutingRecord { iteration: 7, layer: 0, min_recv: 0, mean_recv: 9.0, max_recv: 90 });
        t.push(RoutingRecord { iteration: 7, layer: 1, min_recv: 0, mean_recv: 9.0, max_recv: 50 });
        assert_eq!(t.iteration(7).len(), 2);
        assert_eq!(t.peak_recv(), 90);
    }

    #[test]
    fn routing_csv_shape() {
        let mut t = RoutingTrace::default();
        t.push(RoutingRecord { iteration: 1, layer: 2, min_recv: 3, mean_recv: 4.5, max_recv: 6 });
        let csv = t.to_csv().unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1], "1,2,3,4.5,6");
    }

    #[test]
    fn chunk_grid_layout() {
        let mut t = ChunkTrace::default();
        t.push(ChunkRecord { iteration: 0, layer: 0, chosen_c: 1 });
        t.push(ChunkRecord { iteration: 1, layer: 1, chosen_c: 8 });
        let g = t.grid(2, 2);
        assert_eq!(g[0][0], 1);
        assert_eq!(g[1][1], 8);
        assert_eq!(g[0][1], 0);
    }

    #[test]
    fn mean_per_iteration_trend() {
        let mut t = ChunkTrace::default();
        for l in 0..4 {
            t.push(ChunkRecord { iteration: 0, layer: l, chosen_c: 1 });
            t.push(ChunkRecord { iteration: 1, layer: l, chosen_c: 4 });
        }
        assert_eq!(t.mean_per_iteration(2), vec![1.0, 4.0]);
    }

    #[test]
    fn shared_trace_matches_direct_route_stats() {
        use crate::config::{model_i, paper_parallel};
        let gating = crate::router::GatingSim::new(model_i(), paper_parallel(), 7);
        let trace = SharedRoutingTrace::generate(&gating, 3);
        // 13 MoE layers × 3 iterations, iteration-major
        assert_eq!(trace.moe_layers(), 13);
        assert_eq!(trace.records.len(), 39);
        assert_eq!(trace.seed, 7);
        for it in 0..3u64 {
            let slice = trace.iteration(it);
            assert_eq!(slice.len(), 13);
            for (off, rec) in slice.iter().enumerate() {
                assert_eq!(rec.iteration, it);
                assert_eq!(rec.layer, 3 + off as u64);
                // bit-identical to drawing the same (iteration, layer)
                // directly: route() forks its streams statelessly
                let direct = gating.route(it, rec.layer);
                assert_eq!(rec.max_recv, direct.max_received());
                assert_eq!(rec.min_recv, direct.min_received());
                assert_eq!(rec.mean_recv, direct.summary().mean());
            }
        }
        assert!(trace.peak_recv() > 0);
    }

    #[test]
    fn chunk_trace_json_roundtrip() {
        let mut t = ChunkTrace::default();
        t.push(ChunkRecord { iteration: 3, layer: 9, chosen_c: 2 });
        let j = t.to_json();
        let back = ChunkTrace::from_json(&j).unwrap();
        assert_eq!(back.records, t.records);
    }
}
