//! Launch planning: turn a [`LaunchConfig`] into the shard fleet that
//! will execute it — one [`ShardPlan`] per child process, plus the
//! full set of planned scenario hashes the merge step audits against.
//!
//! Shard ownership reuses [`ShardSpec`]'s round-robin-over-trace-cells
//! semantics exactly as the sweep engine applies them, so the planner
//! can predict — without running anything — which cells and scenarios
//! each child will execute, and no shard ever re-draws another shard's
//! routing traces. The planned hash set is the launch's coverage
//! contract: the merged checkpoints must contain every one of these
//! hashes before a report is published.

use std::path::{Path, PathBuf};

use crate::config::{LaunchConfig, ShardSpec};
use crate::error::Result;
use crate::sweep::checkpoint::planned_hashes;
use crate::sweep::grid;
use crate::trace::provenance::TraceProvenance;

/// One shard process of a launch: its grid split, its checkpoint file
/// (heartbeat + resume target), its stderr log, and the work it owns.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Shard index (0-based) — also the supervisor's shard id.
    pub index: usize,
    /// Total shards in the fleet.
    pub count: usize,
    /// The `--shard i/n` split handed to the child.
    pub spec: ShardSpec,
    /// The child's checkpoint file: its `--checkpoint` target, the
    /// supervisor's heartbeat source, and a merge input.
    pub checkpoint: PathBuf,
    /// Child stderr log (progress lines, errors on crash).
    pub log: PathBuf,
    /// Trace cells this shard owns.
    pub cells: usize,
    /// Scenarios this shard owns.
    pub scenarios: usize,
}

/// The planned fleet plus the coverage contract.
#[derive(Clone, Debug)]
pub struct LaunchPlan {
    /// Effective process count after auto-resolution and cell capping.
    pub procs: usize,
    /// One plan per shard process.
    pub shards: Vec<ShardPlan>,
    /// Every planned scenario as (grid index, content hash), index-
    /// ascending — what the merged checkpoints must cover.
    pub planned: Vec<(usize, String)>,
    /// Trace cells in the grid.
    pub total_cells: usize,
    /// Scenarios in the grid.
    pub total_scenarios: usize,
}

/// Plan the shard fleet for `cfg`, rooting checkpoint/log files in
/// `dir`. Pure planning — nothing is created on disk.
pub fn plan_shards(cfg: &LaunchConfig, dir: &Path) -> Result<LaunchPlan> {
    cfg.validate()?;
    let cells = grid::expand_cells(&cfg.sweep)?;
    let procs = cfg.resolve_procs(cells.len());

    // The coverage contract: hash every scenario of the grid exactly
    // as the children will (scenario hashes are position- and
    // execution-independent, so planner and children always agree).
    // Hashed per trace cell — the envelope serialises once per cell,
    // not once per scenario.
    let planned = planned_hashes(&cfg.sweep, &TraceProvenance::current(cfg.sampler))?;
    let total_scenarios = planned.len();

    let shards = (0..procs)
        .map(|i| {
            let spec = ShardSpec { index: i as u64, count: procs as u64 };
            let owned: Vec<&grid::TraceCell> = cells
                .iter()
                .enumerate()
                .filter(|(ci, _)| spec.owns(*ci))
                .map(|(_, c)| c)
                .collect();
            ShardPlan {
                index: i,
                count: procs,
                spec,
                checkpoint: dir.join(format!("shard-{i}-of-{procs}.jsonl")),
                log: dir.join(format!("shard-{i}-of-{procs}.log")),
                cells: owned.len(),
                scenarios: owned.iter().map(|c| c.scenarios.len()).sum(),
            }
        })
        .collect();

    Ok(LaunchPlan {
        procs,
        shards,
        planned,
        total_cells: cells.len(),
        total_scenarios,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SweepConfig;

    fn launch_cfg(procs: u64) -> LaunchConfig {
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 4, 10));
        cfg.procs = procs;
        cfg
    }

    #[test]
    fn shards_partition_cells_and_scenarios() {
        // 2 models × 4 seeds = 8 cells, 24 scenarios, over 3 shards
        let plan = plan_shards(&launch_cfg(3), Path::new("launchdir")).unwrap();
        assert_eq!(plan.procs, 3);
        assert_eq!(plan.shards.len(), 3);
        assert_eq!(plan.total_cells, 8);
        assert_eq!(plan.total_scenarios, 24);
        assert_eq!(plan.shards.iter().map(|s| s.cells).sum::<usize>(), 8);
        assert_eq!(plan.shards.iter().map(|s| s.scenarios).sum::<usize>(), 24);
        // round-robin over 8 cells: shard 0 owns 3, shards 1-2 own 2+3
        assert!(plan.shards.iter().all(|s| s.cells >= 2));
        for (i, s) in plan.shards.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.count, 3);
            assert_eq!(s.spec, ShardSpec { index: i as u64, count: 3 });
        }
        // distinct per-shard files, rooted in the launch dir
        let mut files: Vec<&PathBuf> =
            plan.shards.iter().map(|s| &s.checkpoint).collect();
        files.dedup();
        assert_eq!(files.len(), 3);
        assert!(plan.shards[0].checkpoint.starts_with("launchdir"));
    }

    #[test]
    fn planned_hashes_enumerate_the_grid() {
        let plan = plan_shards(&launch_cfg(2), Path::new("d")).unwrap();
        assert_eq!(plan.planned.len(), 24);
        for (i, (index, hash)) in plan.planned.iter().enumerate() {
            assert_eq!(*index, i);
            assert_eq!(hash.len(), 16);
        }
        // hashes are distinct (distinct scenarios)
        let mut hashes: Vec<&String> =
            plan.planned.iter().map(|(_, h)| h).collect();
        hashes.sort();
        hashes.dedup();
        assert_eq!(hashes.len(), 24);
        // the sampler choice perturbs every planned hash
        let mut seq = launch_cfg(2);
        seq.sampler = crate::trace::provenance::RouterSampler::Sequential;
        let seq_plan = plan_shards(&seq, Path::new("d")).unwrap();
        assert!(plan
            .planned
            .iter()
            .zip(&seq_plan.planned)
            .all(|((_, a), (_, b))| a != b));
        // and the planned hashes equal the per-scenario reference
        let scenarios = grid::expand(&plan_cfg_sweep()).unwrap();
        let prov = TraceProvenance::current(launch_cfg(2).sampler);
        for (sc, (index, hash)) in scenarios.iter().zip(&plan.planned) {
            assert_eq!(sc.index, *index);
            assert_eq!(
                *hash,
                crate::sweep::checkpoint::scenario_hash(&sc.run, &prov)
            );
        }
    }

    /// The sweep grid `launch_cfg` wraps (for reference hashing).
    fn plan_cfg_sweep() -> crate::config::SweepConfig {
        SweepConfig::paper_grid(7, 4, 10)
    }

    #[test]
    fn procs_cap_to_cells_and_auto_resolves() {
        // 8 cells: asking for 64 procs yields 8 single-cell shards
        let plan = plan_shards(&launch_cfg(64), Path::new("d")).unwrap();
        assert_eq!(plan.procs, 8);
        assert!(plan.shards.iter().all(|s| s.cells == 1));
        // auto (procs = 0) resolves to something in [1, cells]
        let plan = plan_shards(&launch_cfg(0), Path::new("d")).unwrap();
        assert!((1..=8).contains(&plan.procs));
    }

    #[test]
    fn plan_rejects_invalid_config() {
        let mut cfg = launch_cfg(2);
        cfg.sweep.models.clear();
        assert!(plan_shards(&cfg, Path::new("d")).is_err());
    }
}
