//! Thread-per-EP-rank coordinator: dispatch → expert → combine with
//! real row movement over channels and real Pallas-kernel executables.
//!
//! Topology comes from the manifest's `coordinator` block: `ep` worker
//! ranks × `local_experts` experts each, `tokens_per_rank` tokens per
//! micro-batch. The PJRT client is `Rc`-based (not `Send`), so each
//! worker owns its *own* client and compiled executables — exactly the
//! per-device runtime context a real EP group has.
//!
//! One layer pass (Eq. 4, chunked per Eq. 6):
//!
//! 1. every rank routes its tokens with the `router_topk` executable;
//! 2. the leader plans the all-to-all per chunk ([`crate::dispatch`])
//!    and picks the chunk bin — [`ChunkPolicy::Mact`] applies the
//!    Eq. 8/9 logic against each rank's memory budget;
//! 3. per chunk, rows cross `mpsc` channels to their expert's owner,
//!    which assembles the grouped `(E_local, cap, H)` buffer (memory
//!    tracked — OOM surfaces as [`crate::Error::Oom`]), runs the
//!    matching `expert_ffn_c{bin}` executable, and ships results back;
//! 4. source ranks combine with router weights.

use std::sync::mpsc;
use std::sync::Arc;

use crate::cluster::MemoryTracker;
use crate::dispatch::{self, DispatchPlan};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::runtime::{ArtifactStore, HostTensor};
use crate::util::rng::Rng;

/// Coordinator topology (manifest `coordinator` block).
#[derive(Clone, Debug)]
pub struct EpTopology {
    pub ep: usize,
    pub local_experts: usize,
    pub tokens_per_rank: usize,
    pub hidden: usize,
    pub ffn: usize,
    pub top_k: usize,
    pub chunk_bins: Vec<u64>,
}

impl EpTopology {
    pub fn from_manifest(config: &Value) -> Result<Self> {
        let c = config
            .get("coordinator")
            .ok_or_else(|| Error::artifact("manifest missing coordinator block"))?;
        Ok(EpTopology {
            ep: c.req_u64("ep")? as usize,
            local_experts: c.req_u64("local_experts")? as usize,
            tokens_per_rank: c.req_u64("tokens_per_rank")? as usize,
            hidden: c.req_u64("hidden")? as usize,
            ffn: c.req_u64("ffn")? as usize,
            top_k: c.req_u64("top_k")? as usize,
            chunk_bins: c
                .get("chunk_bins")
                .and_then(Value::as_arr)
                .ok_or_else(|| Error::artifact("missing chunk_bins"))?
                .iter()
                .filter_map(Value::as_u64)
                .collect(),
        })
    }

    pub fn global_experts(&self) -> usize {
        self.ep * self.local_experts
    }

    /// Total routed copies per micro-batch across the EP group.
    pub fn total_copies(&self) -> u64 {
        (self.ep * self.tokens_per_rank * self.top_k) as u64
    }

    /// Drop-free per-expert capacity of chunk bin `c` (matches aot.py).
    pub fn capacity(&self, c: u64) -> u64 {
        self.total_copies() / c
    }

    /// Grouped-buffer bytes a rank allocates for one chunk at bin `c`
    /// (input + output + mask, f32).
    pub fn buffer_bytes(&self, c: u64) -> u64 {
        let cap = self.capacity(c);
        let e = self.local_experts as u64;
        let h = self.hidden as u64;
        4 * (e * cap * h /*x*/ + e * cap * h /*out*/ + e * cap /*mask*/)
    }
}

/// Chunk-count policy for the real coordinator.
#[derive(Clone, Copy, Debug)]
pub enum ChunkPolicy {
    /// Always use this bin (Method 2).
    Fixed(u64),
    /// MACT (Method 3): smallest bin whose grouped buffers fit the
    /// per-rank budget (Eq. 8/9 with bytes in place of tokens).
    Mact { budget_bytes: u64 },
}

/// The decision made for one layer pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoordDecision {
    pub chunk_bin: u64,
    pub capacity: u64,
    pub buffer_bytes: u64,
}

/// Output of one coordinated layer pass.
#[derive(Debug)]
pub struct LayerResult {
    /// Combined outputs per rank: `tokens_per_rank × hidden`, row-major.
    pub outputs: Vec<Vec<f32>>,
    pub decision: CoordDecision,
    /// Peak tracked bytes per rank.
    pub peak_bytes: Vec<u64>,
    /// Received copies per rank (the `s''` vector this pass).
    pub received: Vec<u64>,
}

/// Deterministic expert/gate weights for rank `r` (shared generator so
/// the native verifier can rebuild them).
pub fn rank_weights(topo: &EpTopology, seed: u64, rank: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let e = topo.local_experts;
    let h = topo.hidden;
    let g = topo.ffn;
    let mut rng = Rng::new(seed).fork(1000 + rank as u64);
    let scale_h = 1.0 / (h as f64).sqrt();
    let scale_g = 1.0 / (g as f64).sqrt();
    let mut w1 = Vec::with_capacity(e * h * g);
    let mut w3 = Vec::with_capacity(e * h * g);
    let mut w2 = Vec::with_capacity(e * g * h);
    for _ in 0..e * h * g {
        w1.push((rng.normal() * scale_h) as f32);
    }
    for _ in 0..e * h * g {
        w3.push((rng.normal() * scale_h) as f32);
    }
    for _ in 0..e * g * h {
        w2.push((rng.normal() * scale_g) as f32);
    }
    (w1, w3, w2)
}

/// Deterministic gating matrix (replicated on every rank).
pub fn gate_weights(topo: &EpTopology, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed).fork(999);
    let n = topo.hidden * topo.global_experts();
    (0..n).map(|_| (rng.normal() * 0.5) as f32).collect()
}

/// Deterministic input tokens for rank `r`.
pub fn rank_tokens(topo: &EpTopology, seed: u64, rank: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed).fork(2000 + rank as u64);
    (0..topo.tokens_per_rank * topo.hidden)
        .map(|_| rng.normal() as f32)
        .collect()
}

// ---- channel messages -----------------------------------------------------

/// A token row travelling src → expert owner.
struct RowMsg {
    local_expert: u32,
    slot: u32,
    row: Vec<f32>,
    src_rank: u32,
    token: u32,
    k: u8,
}

/// An expert output row travelling owner → src.
struct ResultMsg {
    token: u32,
    k: u8,
    row: Vec<f32>,
}

/// Per-rank worker state living on its own thread.
struct Worker {
    #[allow(dead_code)]
    rank: usize,
    topo: EpTopology,
    store: ArtifactStore,
    w1: Vec<f32>,
    w3: Vec<f32>,
    w2: Vec<f32>,
    tracker: MemoryTracker,
}

impl Worker {
    /// Assemble the grouped buffer from incoming rows and run the
    /// expert executable for one chunk. Returns per-incoming-row
    /// outputs keyed back to (src, token, k).
    fn run_chunk(
        &mut self,
        bin: u64,
        incoming: Vec<RowMsg>,
    ) -> Result<Vec<(u32, ResultMsg)>> {
        let e = self.topo.local_experts;
        let h = self.topo.hidden;
        let cap = self.topo.capacity(bin) as usize;
        let alloc = self.tracker.alloc(self.topo.buffer_bytes(bin))?;
        let mut x = vec![0.0f32; e * cap * h];
        let mut mask = vec![0.0f32; e * cap];
        for msg in &incoming {
            let le = msg.local_expert as usize;
            let slot = msg.slot as usize;
            debug_assert!(slot < cap, "slot {slot} >= cap {cap}");
            x[(le * cap + slot) * h..(le * cap + slot + 1) * h]
                .copy_from_slice(&msg.row);
            mask[le * cap + slot] = 1.0;
        }
        let name = format!("expert_ffn_c{bin}");
        let out = self.store.execute(
            &name,
            &[
                HostTensor::F32(x),
                HostTensor::F32(self.w1.clone()),
                HostTensor::F32(self.w3.clone()),
                HostTensor::F32(self.w2.clone()),
                HostTensor::F32(mask),
            ],
        )?;
        let out = match out.into_iter().next() {
            Some(HostTensor::F32(o)) => o,
            _ => return Err(Error::runtime("expert output not f32")),
        };
        let results = incoming
            .into_iter()
            .map(|msg| {
                let le = msg.local_expert as usize;
                let slot = msg.slot as usize;
                let row = out[(le * cap + slot) * h..(le * cap + slot + 1) * h].to_vec();
                (
                    msg.src_rank,
                    ResultMsg { token: msg.token, k: msg.k, row },
                )
            })
            .collect();
        self.tracker.free(alloc)?;
        Ok(results)
    }
}

/// The coordinator facade.
pub struct EpCoordinator {
    pub topo: EpTopology,
    artifact_dir: std::path::PathBuf,
    pub policy: ChunkPolicy,
    seed: u64,
    /// Per-rank memory capacity for the trackers.
    pub rank_capacity_bytes: u64,
}

impl EpCoordinator {
    pub fn new(
        artifact_dir: impl Into<std::path::PathBuf>,
        policy: ChunkPolicy,
        seed: u64,
    ) -> Result<Self> {
        let dir = artifact_dir.into();
        let store = ArtifactStore::open(&dir)?;
        let topo = EpTopology::from_manifest(&store.manifest)?;
        Ok(EpCoordinator {
            topo,
            artifact_dir: dir,
            policy,
            seed,
            rank_capacity_bytes: 256 << 20,
        })
    }

    /// Apply the policy: MACT = smallest bin whose buffers fit.
    pub fn decide(&self) -> Result<CoordDecision> {
        let bin = match self.policy {
            ChunkPolicy::Fixed(c) => {
                if !self.topo.chunk_bins.contains(&c) {
                    return Err(Error::config(format!(
                        "chunk bin {c} has no exported executable (bins {:?})",
                        self.topo.chunk_bins
                    )));
                }
                c
            }
            ChunkPolicy::Mact { budget_bytes } => *self
                .topo
                .chunk_bins
                .iter()
                .find(|&&c| self.topo.buffer_bytes(c) <= budget_bytes)
                .unwrap_or(self.topo.chunk_bins.last().unwrap()),
        };
        Ok(CoordDecision {
            chunk_bin: bin,
            capacity: self.topo.capacity(bin),
            buffer_bytes: self.topo.buffer_bytes(bin),
        })
    }

    /// Run one full MoE layer pass over deterministic tokens.
    pub fn run_layer(&self) -> Result<LayerResult> {
        let topo = self.topo.clone();
        let ep = topo.ep;
        let decision = self.decide()?;
        let bin = decision.chunk_bin;
        let seed = self.seed;
        let gate = Arc::new(gate_weights(&topo, seed));

        // Phase 1: routing on the main thread's store (replicated gate;
        // any rank's client computes identical results).
        let store = ArtifactStore::open(&self.artifact_dir)?;
        let mut assignments: Vec<Vec<Vec<u32>>> = Vec::with_capacity(ep);
        let mut route_weights: Vec<Vec<f32>> = Vec::with_capacity(ep);
        let mut all_tokens: Vec<Arc<Vec<f32>>> = Vec::with_capacity(ep);
        for rank in 0..ep {
            let tokens = rank_tokens(&topo, seed, rank);
            let out = store.execute(
                "router_topk",
                &[
                    HostTensor::F32(tokens.clone()),
                    HostTensor::F32(gate.as_ref().clone()),
                ],
            )?;
            let weights = out[0].as_f32()?.to_vec();
            let indices = out[1].as_i32()?;
            let per_token: Vec<Vec<u32>> = indices
                .chunks(topo.top_k)
                .map(|c| c.iter().map(|&i| i as u32).collect())
                .collect();
            assignments.push(per_token);
            route_weights.push(weights);
            all_tokens.push(Arc::new(tokens));
        }

        // Phase 2: per-chunk dispatch plans (leader).
        let chunk_tokens = topo.tokens_per_rank / bin as usize;
        let mut plans: Vec<DispatchPlan> = Vec::with_capacity(bin as usize);
        let parallel = crate::config::ParallelConfig {
            tp: 1,
            pp: 1,
            cp: 1,
            ep: ep as u64,
            dp: 1,
            vpp: 1,
            micro_batch: 1,
            global_batch: 1,
        };
        for ci in 0..bin as usize {
            let lo = ci * chunk_tokens;
            let hi = lo + chunk_tokens;
            let chunk_assign: Vec<Vec<Vec<u32>>> = assignments
                .iter()
                .map(|r| r[lo..hi].to_vec())
                .collect();
            plans.push(dispatch::plan(
                &parallel,
                topo.global_experts() as u32,
                &chunk_assign,
                decision.capacity as u32,
            )?);
        }

        // Phase 3: workers. Row channels per rank; a results channel per
        // rank; a final-output channel back to the leader.
        let mut row_txs = Vec::with_capacity(ep);
        let mut row_rxs = Vec::with_capacity(ep);
        for _ in 0..ep {
            let (tx, rx) = mpsc::channel::<RowMsg>();
            row_txs.push(tx);
            row_rxs.push(Some(rx));
        }
        let mut res_txs = Vec::with_capacity(ep);
        let mut res_rxs = Vec::with_capacity(ep);
        for _ in 0..ep {
            let (tx, rx) = mpsc::channel::<ResultMsg>();
            res_txs.push(tx);
            res_rxs.push(Some(rx));
        }
        let (done_tx, done_rx) = mpsc::channel::<Result<(usize, Vec<f32>, u64, u64)>>();

        let plans = Arc::new(plans);
        let mut handles = Vec::with_capacity(ep);
        for rank in 0..ep {
            let topo_c = topo.clone();
            let dir = self.artifact_dir.clone();
            let my_rows = row_rxs[rank].take().unwrap();
            let my_results = res_rxs[rank].take().unwrap();
            let row_txs = row_txs.clone();
            let res_txs = res_txs.clone();
            let done = done_tx.clone();
            let plans = plans.clone();
            let tokens = all_tokens[rank].clone();
            let weights = route_weights[rank].clone();
            let cap_bytes = self.rank_capacity_bytes;
            let h = topo.hidden;
            let tpr = topo.tokens_per_rank;
            let tk = topo.top_k;
            handles.push(std::thread::spawn(move || {
                let work = || -> Result<(Vec<f32>, u64, u64)> {
                    let store = ArtifactStore::open(&dir)?;
                    let (w1, w3, w2) = rank_weights(&topo_c, seed, rank);
                    let mut worker = Worker {
                        rank,
                        topo: topo_c.clone(),
                        store,
                        w1,
                        w3,
                        w2,
                        tracker: MemoryTracker::new(rank, cap_bytes),
                    };
                    let mut combined = vec![0.0f32; tpr * h];
                    let mut received_total = 0u64;
                    let chunk_tokens = tpr / plans.len();
                    for (ci, plan) in plans.iter().enumerate() {
                        // send my rows
                        let mut expected_results = 0usize;
                        for p in &plan.placements {
                            if p.route.src_rank as usize != rank {
                                continue;
                            }
                            expected_results += 1;
                            let slot = p.slot.ok_or_else(|| {
                                Error::schedule("drop-free plan overflowed")
                            })?;
                            // chunk-local token index → global token index
                            let tok_global = p.route.token as usize + ci * chunk_tokens;
                            let row = tokens[tok_global * h..(tok_global + 1) * h].to_vec();
                            row_txs[p.dst_rank as usize]
                                .send(RowMsg {
                                    local_expert: p.local_expert,
                                    slot,
                                    row,
                                    src_rank: rank as u32,
                                    token: tok_global as u32,
                                    k: p.route.k,
                                })
                                .map_err(|_| Error::runtime("row channel closed"))?;
                        }
                        // receive the rows destined to me
                        let mine: u64 = plan
                            .send_counts
                            .iter()
                            .map(|src| src[rank])
                            .sum();
                        received_total += mine;
                        let mut incoming = Vec::with_capacity(mine as usize);
                        for _ in 0..mine {
                            incoming.push(my_rows.recv().map_err(|_| {
                                Error::runtime("row channel closed early")
                            })?);
                        }
                        // expert compute for this chunk
                        let results = worker.run_chunk(bin, incoming)?;
                        for (src, res) in results {
                            res_txs[src as usize]
                                .send(res)
                                .map_err(|_| Error::runtime("result channel closed"))?;
                        }
                        // combine my own tokens' results for this chunk
                        for _ in 0..expected_results {
                            let r = my_results.recv().map_err(|_| {
                                Error::runtime("result channel closed early")
                            })?;
                            let w = weights[r.token as usize * tk + r.k as usize];
                            let dst =
                                &mut combined[r.token as usize * h..(r.token as usize + 1) * h];
                            for (d, s) in dst.iter_mut().zip(&r.row) {
                                *d += w * s;
                            }
                        }
                    }
                    Ok((combined, worker.tracker.peak(), received_total))
                };
                let _ = done.send(work().map(|(c, p, r)| (rank, c, p, r)));
            }));
        }
        drop(done_tx);
        drop(row_txs);
        drop(res_txs);

        let mut outputs = vec![Vec::new(); ep];
        let mut peaks = vec![0u64; ep];
        let mut received = vec![0u64; ep];
        let mut first_err = None;
        for _ in 0..ep {
            match done_rx.recv() {
                Ok(Ok((rank, out, peak, recv))) => {
                    outputs[rank] = out;
                    peaks[rank] = peak;
                    received[rank] = recv;
                }
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(Error::runtime("worker vanished"));
                }
            }
        }
        for h in handles {
            let _ = h.join();
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(LayerResult { outputs, decision, peak_bytes: peaks, received })
    }
}

/// A pure-rust verifier of the coordinated layer: recomputes the full
/// drop-free MoE pass (softmax router, top-k ties toward lower index,
/// SwiGLU experts, weighted combine) on the CPU with the same seeded
/// weights/tokens. Integration tests assert the coordinator's channel +
/// executable pipeline matches this to float tolerance, and that the
/// result is invariant to the chunk bin.
pub fn native_reference(topo: &EpTopology, seed: u64) -> Vec<Vec<f32>> {
    let h = topo.hidden;
    let g = topo.ffn;
    let e_l = topo.local_experts;
    let gate = gate_weights(topo, seed);
    let per_rank_w: Vec<_> = (0..topo.ep).map(|r| rank_weights(topo, seed, r)).collect();
    let mut outputs = Vec::with_capacity(topo.ep);
    for rank in 0..topo.ep {
        let tokens = rank_tokens(topo, seed, rank);
        let mut out = vec![0.0f32; topo.tokens_per_rank * h];
        for t in 0..topo.tokens_per_rank {
            let x = &tokens[t * h..(t + 1) * h];
            // router: logits = x @ gate  (gate is h × E_global)
            let eg = topo.global_experts();
            let mut logits = vec![0.0f64; eg];
            for (i, &xi) in x.iter().enumerate() {
                let row = &gate[i * eg..(i + 1) * eg];
                for (l, &w) in logits.iter_mut().zip(row) {
                    *l += xi as f64 * w as f64;
                }
            }
            let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let exps: Vec<f64> = logits.iter().map(|&l| (l - max).exp()).collect();
            let denom: f64 = exps.iter().sum();
            let mut probs: Vec<f64> = exps.iter().map(|&e| e / denom).collect();
            // top-k, ties toward lower index
            let mut picks = Vec::with_capacity(topo.top_k);
            for _ in 0..topo.top_k {
                let (mut bi, mut bv) = (0usize, f64::NEG_INFINITY);
                for (i, &p) in probs.iter().enumerate() {
                    if p > bv {
                        bv = p;
                        bi = i;
                    }
                }
                picks.push((bi, bv));
                probs[bi] = f64::NEG_INFINITY;
            }
            let wsum: f64 = picks.iter().map(|&(_, v)| v).sum();
            for &(expert, pv) in &picks {
                let owner = expert / e_l;
                let local = expert % e_l;
                let (w1, w3, w2) = &per_rank_w[owner];
                // SwiGLU: out = (silu(x·w1) * (x·w3)) · w2
                let mut act = vec![0.0f64; g];
                for gi in 0..g {
                    let mut a1 = 0.0f64;
                    let mut a3 = 0.0f64;
                    for (i, &xi) in x.iter().enumerate() {
                        a1 += xi as f64 * w1[(local * h + i) * g + gi] as f64;
                        a3 += xi as f64 * w3[(local * h + i) * g + gi] as f64;
                    }
                    let silu = a1 / (1.0 + (-a1).exp());
                    act[gi] = silu * a3;
                }
                let weight = (pv / wsum) as f32;
                let dst = &mut out[t * h..(t + 1) * h];
                for (i, d) in dst.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for (gi, &a) in act.iter().enumerate() {
                        acc += a * w2[(local * g + gi) * h + i] as f64;
                    }
                    *d += weight * acc as f32;
                }
            }
        }
        outputs.push(out);
    }
    outputs
}
