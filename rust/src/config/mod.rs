//! Configuration: model dimensions (paper Table 1/3), parallelism
//! layout, training setup, and the MemFine method selection.
//!
//! Presets `model_i()` / `model_ii()` reproduce Table 3 exactly; the
//! `tiny()` preset matches the AOT-exported mini model used by the
//! real-execution coordinator. Configs round-trip through the crate's
//! JSON module and are validated before use.

use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::trace::provenance::{RngVersion, RouterSampler};

/// Model architecture parameters — the paper's Table 1 notation.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Total transformer layers (paper `L`).
    pub layers: u64,
    /// Leading dense (non-MoE) layers (paper `d_l`).
    pub dense_layers: u64,
    /// Sequence length (paper `s`).
    pub seq: u64,
    /// Hidden size (paper `h`).
    pub hidden: u64,
    /// Attention head count (paper `a`).
    pub heads: u64,
    /// Per-head dimension (paper `h_d`).
    pub head_dim: u64,
    /// KV head count (paper `k_a`).
    pub kv_heads: u64,
    /// Dense-layer FFN intermediate size (paper `g_d`).
    pub ffn_dense: u64,
    /// Expert FFN intermediate size (paper `g_e`).
    pub ffn_expert: u64,
    /// Routed experts in total (paper router width `e_n`).
    pub n_experts: u64,
    /// Experts activated per token (paper `t_k`).
    pub top_k: u64,
    /// Vocabulary size (paper `V`).
    pub vocab: u64,
    /// Low-rank attention projection rank (Table 3 column `r`; enters
    /// static memory only).
    pub q_lora_rank: u64,
}

impl ModelConfig {
    /// Parameter count of one MoE layer's experts that live on a single
    /// EP rank hosting `local_experts` experts (SwiGLU: 3 matrices).
    pub fn expert_params_per_rank(&self, local_experts: u64) -> u64 {
        3 * self.hidden * self.ffn_expert * local_experts
    }

    /// Parameter count of one layer's attention block. With
    /// `q_lora_rank > 0` this models DeepSeek-style MLA (low-rank q and
    /// kv projections, kv rank 512 as in DeepSeek-V3); otherwise plain
    /// dense q/k/v/o.
    pub fn attention_params(&self) -> u64 {
        let out = (self.heads * self.head_dim) * self.hidden;
        if self.q_lora_rank > 0 {
            const KV_RANK: u64 = 512;
            let q = self.hidden * self.q_lora_rank
                + self.q_lora_rank * self.heads * self.head_dim;
            let kv = self.hidden * KV_RANK
                + 2 * KV_RANK * self.kv_heads * self.head_dim;
            q + kv + out
        } else {
            let qkv = self.hidden * (self.heads * self.head_dim)
                + 2 * self.hidden * (self.kv_heads * self.head_dim);
            qkv + out
        }
    }

    /// Dense FFN parameters of one dense layer (SwiGLU: 3 matrices).
    pub fn dense_ffn_params(&self) -> u64 {
        3 * self.hidden * self.ffn_dense
    }

    /// Router (gating) parameters of one MoE layer.
    pub fn router_params(&self) -> u64 {
        self.hidden * self.n_experts
    }

    pub fn validate(&self) -> Result<()> {
        if self.layers == 0 || self.hidden == 0 || self.seq == 0 {
            return Err(Error::config("layers/hidden/seq must be positive"));
        }
        if self.dense_layers > self.layers {
            return Err(Error::config(format!(
                "dense_layers {} > layers {}",
                self.dense_layers, self.layers
            )));
        }
        if self.top_k == 0 || self.top_k > self.n_experts {
            return Err(Error::config(format!(
                "top_k {} must be in [1, n_experts={}]",
                self.top_k, self.n_experts
            )));
        }
        if self.kv_heads > self.heads {
            return Err(Error::config("kv_heads > heads"));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("layers", json::num(self.layers as f64)),
            ("dense_layers", json::num(self.dense_layers as f64)),
            ("seq", json::num(self.seq as f64)),
            ("hidden", json::num(self.hidden as f64)),
            ("heads", json::num(self.heads as f64)),
            ("head_dim", json::num(self.head_dim as f64)),
            ("kv_heads", json::num(self.kv_heads as f64)),
            ("ffn_dense", json::num(self.ffn_dense as f64)),
            ("ffn_expert", json::num(self.ffn_expert as f64)),
            ("n_experts", json::num(self.n_experts as f64)),
            ("top_k", json::num(self.top_k as f64)),
            ("vocab", json::num(self.vocab as f64)),
            ("q_lora_rank", json::num(self.q_lora_rank as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let cfg = ModelConfig {
            layers: v.req_u64("layers")?,
            dense_layers: v.req_u64("dense_layers")?,
            seq: v.req_u64("seq")?,
            hidden: v.req_u64("hidden")?,
            heads: v.req_u64("heads")?,
            head_dim: v.req_u64("head_dim")?,
            kv_heads: v.req_u64("kv_heads")?,
            ffn_dense: v.req_u64("ffn_dense")?,
            ffn_expert: v.req_u64("ffn_expert")?,
            n_experts: v.req_u64("n_experts")?,
            top_k: v.req_u64("top_k")?,
            vocab: v.req_u64("vocab")?,
            q_lora_rank: v.req_u64("q_lora_rank")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Parallelism layout — Table 1's `t, p, c, e, d, v, b, g_bs`.
#[derive(Clone, Debug, PartialEq)]
pub struct ParallelConfig {
    /// Tensor parallel size (`t`).
    pub tp: u64,
    /// Pipeline parallel size (`p`).
    pub pp: u64,
    /// Context parallel size (`c`).
    pub cp: u64,
    /// Expert parallel size (`e`).
    pub ep: u64,
    /// Data parallel size (`d`).
    pub dp: u64,
    /// Virtual pipeline stages per GPU (`v`).
    pub vpp: u64,
    /// Micro-batch size (`b`).
    pub micro_batch: u64,
    /// Global batch size in sequences (`g_bs`).
    pub global_batch: u64,
}

impl ParallelConfig {
    /// Total GPUs in the job.
    pub fn world_size(&self) -> u64 {
        // EP ranks are carved out of the DP×TP group in Megatron-style
        // layouts; for the paper's setting (t=1, d=1, e=32, p=4) the
        // world is e × p.
        self.tp.max(self.ep) * self.pp * self.dp.max(1) * self.cp
    }

    /// Transformer layers hosted by one pipeline stage.
    pub fn layers_per_stage(&self, total_layers: u64) -> u64 {
        total_layers.div_ceil(self.pp * self.vpp)
    }

    /// Micro-batches per iteration per DP replica.
    pub fn micro_batches(&self) -> u64 {
        self.global_batch / (self.micro_batch * self.dp.max(1))
    }

    /// The paper's stored-activation multiplier
    /// `m_g = v·p + p − 2·r_pp − 1` for pipeline rank `r_pp`
    /// (1F1B with interleaving; stage 0 holds the most).
    pub fn m_g(&self, pp_rank: u64) -> u64 {
        let raw = (self.vpp * self.pp + self.pp) as i64 - 2 * pp_rank as i64 - 1;
        raw.max(1) as u64
    }

    pub fn validate(&self, model: &ModelConfig) -> Result<()> {
        for (name, v) in [
            ("tp", self.tp),
            ("pp", self.pp),
            ("cp", self.cp),
            ("ep", self.ep),
            ("dp", self.dp),
            ("vpp", self.vpp),
            ("micro_batch", self.micro_batch),
            ("global_batch", self.global_batch),
        ] {
            if v == 0 {
                return Err(Error::config(format!("{name} must be positive")));
            }
        }
        if model.layers % (self.pp * self.vpp) != 0 {
            return Err(Error::config(format!(
                "layers {} not divisible by pp*vpp {}",
                model.layers,
                self.pp * self.vpp
            )));
        }
        if model.n_experts % self.ep != 0 {
            return Err(Error::config(format!(
                "n_experts {} not divisible by ep {}",
                model.n_experts, self.ep
            )));
        }
        if self.global_batch % (self.micro_batch * self.dp) != 0 {
            return Err(Error::config(
                "global_batch must be divisible by micro_batch*dp",
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("tp", json::num(self.tp as f64)),
            ("pp", json::num(self.pp as f64)),
            ("cp", json::num(self.cp as f64)),
            ("ep", json::num(self.ep as f64)),
            ("dp", json::num(self.dp as f64)),
            ("vpp", json::num(self.vpp as f64)),
            ("micro_batch", json::num(self.micro_batch as f64)),
            ("global_batch", json::num(self.global_batch as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(ParallelConfig {
            tp: v.req_u64("tp")?,
            pp: v.req_u64("pp")?,
            cp: v.req_u64("cp")?,
            ep: v.req_u64("ep")?,
            dp: v.req_u64("dp")?,
            vpp: v.req_u64("vpp")?,
            micro_batch: v.req_u64("micro_batch")?,
            global_batch: v.req_u64("global_batch")?,
        })
    }
}

/// Which memory strategy a run uses — the paper's Methods 1/2/3.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Method 1: no chunk splitting; full activation recomputation
    /// (the Megatron-LM baseline).
    FullRecompute,
    /// Method 2: MemFine with a fixed chunk threshold `c_k`.
    FixedChunk(u64),
    /// Method 3: MemFine with MACT dynamic tuning over the given bins.
    Mact(Vec<u64>),
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::FullRecompute => "method1/full-recompute".into(),
            Method::FixedChunk(c) => format!("method2/fixed-c{c}"),
            Method::Mact(bins) => format!(
                "method3/mact-bins{}",
                bins.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
            ),
        }
    }

    /// Parse the CLI/sweep shorthand: `1` (full recompute), `2` or
    /// `2:c` (fixed chunk, default c=8), `3` or `3:b1.b2...` (MACT,
    /// default bins 1,2,4,8 — bins dot-separated so method lists stay
    /// comma-separated).
    pub fn parse(spec: &str) -> Result<Method> {
        let (head, arg) = match spec.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (spec, None),
        };
        match head.trim() {
            "1" => {
                if arg.is_some() {
                    return Err(Error::config(format!(
                        "method 1 takes no argument (got '{spec}'; did you mean 2:...?)"
                    )));
                }
                Ok(Method::FullRecompute)
            }
            "2" => {
                let c = match arg {
                    None => 8,
                    Some(a) => a.trim().parse().map_err(|_| {
                        Error::config(format!("bad fixed-chunk spec '{spec}'"))
                    })?,
                };
                Ok(Method::FixedChunk(c))
            }
            "3" => {
                let bins = match arg {
                    None => vec![1, 2, 4, 8],
                    Some(a) => a
                        .split('.')
                        .map(|b| {
                            b.trim().parse().map_err(|_| {
                                Error::config(format!("bad MACT bins in '{spec}'"))
                            })
                        })
                        .collect::<Result<Vec<u64>>>()?,
                };
                Ok(Method::Mact(bins))
            }
            other => Err(Error::config(format!(
                "unknown method '{other}' (expected 1, 2[:c] or 3[:b.b...])"
            ))),
        }
    }

    pub fn to_json(&self) -> Value {
        match self {
            Method::FullRecompute => json::obj(vec![("kind", json::s("full_recompute"))]),
            Method::FixedChunk(c) => json::obj(vec![
                ("kind", json::s("fixed_chunk")),
                ("chunk", json::num(*c as f64)),
            ]),
            Method::Mact(bins) => json::obj(vec![
                ("kind", json::s("mact")),
                (
                    "bins",
                    json::arr(bins.iter().map(|&b| json::num(b as f64)).collect()),
                ),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        match v.req_str("kind")? {
            "full_recompute" => Ok(Method::FullRecompute),
            "fixed_chunk" => Ok(Method::FixedChunk(v.req_u64("chunk")?)),
            "mact" => {
                let bins = v
                    .get("bins")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| Error::config("mact method missing bins"))?
                    .iter()
                    .map(|b| {
                        b.as_u64().ok_or_else(|| Error::config("bad mact bin"))
                    })
                    .collect::<Result<Vec<u64>>>()?;
                Ok(Method::Mact(bins))
            }
            other => Err(Error::config(format!("unknown method kind '{other}'"))),
        }
    }
}

/// Hardware + method envelope for a training run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub parallel: ParallelConfig,
    pub method: Method,
    /// GPU memory capacity in bytes (paper: 64 GB).
    pub gpu_mem_bytes: u64,
    /// Usable fraction α of GPU memory (paper Eq. 3).
    pub alpha: f64,
    /// Bytes per activation element (paper `D_t`; BF16 ⇒ 2).
    pub dtype_bytes: u64,
    /// Bytes per parameter for static memory (weights+grads+optimizer,
    /// Megatron-style distributed optimizer; see memory::static docs).
    pub static_bytes_per_param: f64,
    /// Constant per-GPU framework overhead counted as static memory:
    /// CUDA context, NCCL buffers, allocator workspace/fragmentation.
    pub static_overhead_bytes: u64,
    /// Allow MemFine's selective recomputation (store attention
    /// activations when the chunked MoE peak leaves headroom). Always
    /// true in the paper's method; the ablation bench toggles it.
    pub allow_selective_recompute: bool,
    /// Training iterations to simulate.
    pub iterations: u64,
    /// RNG seed for routing traces.
    pub seed: u64,
}

impl RunConfig {
    /// Canonical JSON form of the full run envelope. This is what the
    /// sweep checkpoint layer hashes to identify a scenario across
    /// processes/hosts: every field that influences the simulation
    /// output is present, keys serialise sorted, and numbers print in
    /// the writer's shortest round-trip form — so two hosts expanding
    /// the same grid derive the same scenario hashes.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", self.model.to_json()),
            ("parallel", self.parallel.to_json()),
            ("method", self.method.to_json()),
            ("gpu_mem_bytes", json::num(self.gpu_mem_bytes as f64)),
            ("alpha", json::num(self.alpha)),
            ("dtype_bytes", json::num(self.dtype_bytes as f64)),
            ("static_bytes_per_param", json::num(self.static_bytes_per_param)),
            (
                "static_overhead_bytes",
                json::num(self.static_overhead_bytes as f64),
            ),
            (
                "allow_selective_recompute",
                Value::Bool(self.allow_selective_recompute),
            ),
            ("iterations", json::num(self.iterations as f64)),
            ("seed", json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let cfg = RunConfig {
            model: ModelConfig::from_json(
                v.get("model").ok_or_else(|| Error::config("run missing model"))?,
            )?,
            parallel: ParallelConfig::from_json(
                v.get("parallel")
                    .ok_or_else(|| Error::config("run missing parallel"))?,
            )?,
            method: Method::from_json(
                v.get("method").ok_or_else(|| Error::config("run missing method"))?,
            )?,
            gpu_mem_bytes: v.req_u64("gpu_mem_bytes")?,
            alpha: v.req_f64("alpha")?,
            dtype_bytes: v.req_u64("dtype_bytes")?,
            static_bytes_per_param: v.req_f64("static_bytes_per_param")?,
            static_overhead_bytes: v.req_u64("static_overhead_bytes")?,
            allow_selective_recompute: v
                .get("allow_selective_recompute")
                .and_then(Value::as_bool)
                .ok_or_else(|| Error::config("run missing allow_selective_recompute"))?,
            iterations: v.req_u64("iterations")?,
            seed: v.req_u64("seed")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        self.model.validate()?;
        self.parallel.validate(&self.model)?;
        if !(0.0..=1.0).contains(&self.alpha) {
            return Err(Error::config("alpha must be in [0,1]"));
        }
        if self.gpu_mem_bytes == 0 {
            return Err(Error::config("gpu_mem_bytes must be positive"));
        }
        if let Method::FixedChunk(0) = self.method {
            return Err(Error::config("fixed chunk must be ≥ 1"));
        }
        if let Method::Mact(bins) = &self.method {
            if bins.is_empty() {
                return Err(Error::config("MACT bins must be non-empty"));
            }
            if bins.windows(2).any(|w| w[0] >= w[1]) {
                return Err(Error::config("MACT bins must be strictly increasing"));
            }
        }
        Ok(())
    }
}

pub const GB: u64 = 1024 * 1024 * 1024;

/// Table 3, Model I: 16-layer reduced DeepSeek-V3.
pub fn model_i() -> ModelConfig {
    ModelConfig {
        layers: 16,
        dense_layers: 3,
        seq: 4096,
        hidden: 7168,
        heads: 128,
        head_dim: 128,
        kv_heads: 128,
        ffn_dense: 18432,
        ffn_expert: 2048,
        n_experts: 256,
        top_k: 8,
        vocab: 129280,
        q_lora_rank: 1536,
    }
}

/// Table 3, Model II: the 8-layer variant.
pub fn model_ii() -> ModelConfig {
    ModelConfig { layers: 8, ..model_i() }
}

/// The paper's parallelism: t=1, p=4, e=32, d=1, c=1, v=1, b=1, g_bs=960.
pub fn paper_parallel() -> ParallelConfig {
    ParallelConfig {
        tp: 1,
        pp: 4,
        cp: 1,
        ep: 32,
        dp: 1,
        vpp: 1,
        micro_batch: 1,
        global_batch: 960,
    }
}

/// Paper experiment envelope for the given model and method
/// (32 GPUs × 64 GB, BF16).
pub fn paper_run(model: ModelConfig, method: Method) -> RunConfig {
    RunConfig {
        model,
        parallel: paper_parallel(),
        method,
        gpu_mem_bytes: 64 * GB,
        // Table 4 shows Model II Method 1 training at 62.4 GB total on
        // a 64 GB device — the usable fraction is ≈ 0.98.
        alpha: 0.98,
        dtype_bytes: 2,
        // d = 1 means the FP32 optimizer is NOT sharded: BF16 weights
        // (2) + FP32 main grads (4) + FP32 master/m/v (12) ≈ 18 B/param
        // upper bound; 16 calibrated to Table 4's static column
        // (43.0 GB Model I / 39.5 GB Model II).
        static_bytes_per_param: 16.0,
        // CUDA context + NCCL rings + allocator slack on a production
        // Megatron job — calibrated with the bytes/param so Table 4's
        // static column lands on 43.0 / 39.5 GB.
        static_overhead_bytes: 10 * GB,
        allow_selective_recompute: true,
        iterations: 25,
        seed: 7,
    }
}

/// Look up a Table-3 model preset by its CLI/sweep name.
pub fn model_by_name(name: &str) -> Result<ModelConfig> {
    match name.trim().to_ascii_lowercase().as_str() {
        "i" | "1" => Ok(model_i()),
        "ii" | "2" => Ok(model_ii()),
        other => Err(Error::config(format!(
            "unknown model '{other}' (expected i or ii)"
        ))),
    }
}

/// Grid specification for the scenario sweep engine
/// ([`crate::sweep`]): the cross product of models × methods × seeds,
/// each simulated for `iterations` iterations under the paper's
/// hardware envelope. This is the config surface every table/figure
/// sweep and future scaling/ablation study is expressed in.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepConfig {
    /// Table-3 model preset names ("i", "ii").
    pub models: Vec<String>,
    /// MemFine methods to compare on identical routing traces.
    pub methods: Vec<Method>,
    /// RNG seeds; each (model, method) cell runs once per seed on the
    /// routing trace that seed determines, so methods are compared
    /// *paired* per seed exactly as the paper's tables are.
    pub seeds: Vec<u64>,
    /// Simulated training iterations per scenario.
    pub iterations: u64,
}

impl SweepConfig {
    /// Total scenarios in the grid.
    pub fn scenario_count(&self) -> usize {
        self.models.len() * self.methods.len() * self.seeds.len()
    }

    pub fn validate(&self) -> Result<()> {
        if self.models.is_empty() || self.methods.is_empty() || self.seeds.is_empty() {
            return Err(Error::config(
                "sweep grid needs at least one model, method and seed",
            ));
        }
        if self.iterations == 0 {
            return Err(Error::config("sweep iterations must be positive"));
        }
        if let Some(&s) = self.seeds.iter().find(|&&s| s > MAX_JSON_SEED) {
            return Err(Error::config(format!(
                "seed {s} exceeds 2^53 and would not round-trip the JSON artifact"
            )));
        }
        // Duplicate axis entries would double-count scenario rows into
        // one aggregation cell (cells are keyed by model × method
        // name), so every axis must be duplicate-free. Models dedup on
        // the *resolved* preset, catching aliases ("i" vs "1").
        let mut seen_models: Vec<ModelConfig> = Vec::new();
        for m in &self.models {
            let resolved = model_by_name(m)?;
            if seen_models.contains(&resolved) {
                return Err(Error::config(format!("duplicate sweep model '{m}'")));
            }
            seen_models.push(resolved);
        }
        let mut seen_methods = std::collections::BTreeSet::new();
        for method in &self.methods {
            // reuse RunConfig's method validation by probing a run
            let run = paper_run(model_i(), method.clone());
            run.validate()?;
            if !seen_methods.insert(method.name()) {
                return Err(Error::config(format!(
                    "duplicate sweep method '{}'",
                    method.name()
                )));
            }
        }
        let mut seen_seeds = std::collections::BTreeSet::new();
        for &s in &self.seeds {
            if !seen_seeds.insert(s) {
                return Err(Error::config(format!("duplicate sweep seed {s}")));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            (
                "models",
                json::arr(self.models.iter().map(|m| json::s(m.clone())).collect()),
            ),
            (
                "methods",
                json::arr(self.methods.iter().map(Method::to_json).collect()),
            ),
            (
                "seeds",
                json::arr(self.seeds.iter().map(|&s| json::num(s as f64)).collect()),
            ),
            ("iterations", json::num(self.iterations as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let models = v
            .get("models")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::config("sweep missing models"))?
            .iter()
            .map(|m| {
                m.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::config("bad model name"))
            })
            .collect::<Result<Vec<_>>>()?;
        let methods = v
            .get("methods")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::config("sweep missing methods"))?
            .iter()
            .map(Method::from_json)
            .collect::<Result<Vec<_>>>()?;
        let seeds = v
            .get("seeds")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::config("sweep missing seeds"))?
            .iter()
            .map(|s| s.as_u64().ok_or_else(|| Error::config("bad seed")))
            .collect::<Result<Vec<_>>>()?;
        let cfg = SweepConfig {
            models,
            methods,
            seeds,
            iterations: v.req_u64("iterations")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The paper's default comparison grid: Models I/II × Methods
    /// 1/2/3 × `n_seeds` derived seeds.
    pub fn paper_grid(base_seed: u64, n_seeds: usize, iterations: u64) -> Self {
        SweepConfig {
            models: vec!["i".into(), "ii".into()],
            methods: vec![
                Method::FullRecompute,
                Method::FixedChunk(8),
                Method::Mact(vec![1, 2, 4, 8]),
            ],
            seeds: derive_seeds(base_seed, n_seeds),
            iterations,
        }
    }
}

/// One shard of a sweep grid split across processes/hosts: `index` of
/// `count` (CLI `--shard i/n`). Ownership is round-robin —
/// `index == item_index % count` — applied by the sweep engine to
/// **trace cells** (the (model, seed) groups that share one routed-
/// token stream), never to individual scenarios: splitting a cell
/// would force every shard to re-draw the same routing trace. Cells
/// are homogeneous (one scenario per method each), so round-robin
/// over cells keeps shards balanced.
///
/// Sharding is an *execution* parameter, not part of the grid
/// identity: it never enters [`SweepConfig`]'s JSON or the scenario
/// hash, so checkpoints written by any shard split merge into the
/// byte-identical unsharded artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: u64,
    pub count: u64,
}

impl ShardSpec {
    /// Parse the CLI form `i/n` (e.g. `0/4`), requiring `i < n`.
    pub fn parse(spec: &str) -> Result<ShardSpec> {
        let (i, n) = spec
            .split_once('/')
            .ok_or_else(|| Error::config(format!("shard spec '{spec}' is not i/n")))?;
        let index: u64 = i
            .trim()
            .parse()
            .map_err(|_| Error::config(format!("bad shard index in '{spec}'")))?;
        let count: u64 = n
            .trim()
            .parse()
            .map_err(|_| Error::config(format!("bad shard count in '{spec}'")))?;
        if count == 0 || index >= count {
            return Err(Error::config(format!(
                "shard {index}/{count}: index must be < count ≥ 1"
            )));
        }
        Ok(ShardSpec { index, count })
    }

    /// Does this shard own the work item at `index` (the sweep engine
    /// passes trace-cell indices)?
    pub fn owns(&self, index: usize) -> bool {
        index as u64 % self.count == self.index
    }
}

/// Derive `n` independent per-scenario seeds from a base seed
/// (splitmix64 walk via the crate RNG). Scenario results depend only
/// on these values — never on worker count or scheduling order — so a
/// sweep is bit-reproducible from `(base_seed, n)`. Seeds are clamped
/// to 53 bits so they survive the JSON artifact round-trip exactly
/// (the in-tree JSON stores numbers as f64; see [`MAX_JSON_SEED`])
/// while keeping birthday collisions negligible even for
/// million-scenario grids (the duplicate-seed validation would
/// otherwise reject large derived sets).
pub fn derive_seeds(base_seed: u64, n: usize) -> Vec<u64> {
    let mut rng = crate::util::rng::Rng::new(base_seed);
    (0..n).map(|_| rng.next_u64() >> 11).collect()
}

/// Largest seed value that round-trips losslessly through the JSON
/// artifact (f64 integer precision, 2^53).
pub const MAX_JSON_SEED: u64 = 1 << 53;

/// Spec of an orchestrated multi-process sweep launch
/// ([`crate::orchestrator`]): the grid itself plus the supervision
/// parameters of the shard fleet that executes it. Like
/// [`SweepConfig`], a `LaunchConfig` round-trips through JSON so a
/// campaign can be captured in a single file (`memfine launch
/// --config launch.json`); unlike `SweepConfig` it is **not** part of
/// any scenario identity — the merged artifact depends only on
/// `sweep` (and `fast_router`), never on how many processes ran it or
/// how often they were healed.
#[derive(Clone, Debug, PartialEq)]
pub struct LaunchConfig {
    /// The grid to execute — the only identity-bearing field.
    pub sweep: SweepConfig,
    /// Shard processes to spawn (0 = auto: available cores divided by
    /// `workers_per_proc`, capped to the grid's trace-cell count).
    pub procs: u64,
    /// Worker threads each shard process runs (`memfine sweep
    /// --workers`); procs × workers_per_proc ≈ one machine's cores.
    pub workers_per_proc: u64,
    /// A shard whose checkpoint file has not grown for this long is
    /// considered stalled, killed, and relaunched with `--resume`.
    pub stall_timeout_ms: u64,
    /// Supervisor poll interval for child exits and heartbeats.
    pub poll_ms: u64,
    /// Relaunches allowed per shard *failure episode* (beyond the
    /// initial spawn) before the supervisor gives up on it. An episode
    /// ends — and this budget resets — whenever the shard shows fresh
    /// checkpoint progress.
    pub max_retries: u64,
    /// Fleet-wide relaunch budget for the whole campaign (0 =
    /// unlimited). The backstop against a shard that crashes in a loop
    /// while still appending bytes each attempt: every append resets
    /// its episode budget, so only this bound can stop it.
    pub campaign_retries: u64,
    /// Base backoff before the first relaunch of an episode, doubling
    /// per relaunch (capped at 10 s) with deterministic jitter; 0
    /// disables backoff.
    pub backoff_ms: u64,
    /// Quarantine a persistently-failing shard's checkpoint (rename it
    /// aside) when it gives up without progress, so the merge
    /// catch-up redistributes its cells. On by default.
    pub quarantine: bool,
    /// Router sampler the campaign draws with (part of every scenario
    /// hash and trace-cache key). Defaults to the splitting
    /// multinomial; `--router seq` reproduces pre-flip campaigns.
    pub sampler: RouterSampler,
    /// RNG generation the campaign draws with (`--rng`, forwarded to
    /// every child sweep). Part of every scenario hash and trace-cache
    /// key, exactly like `sampler`. Defaults to v1; absent in
    /// pre-counter-RNG launch.json files, which therefore keep
    /// resolving to the v1 streams they were recorded under.
    pub rng: RngVersion,
    /// Pin each shard's worker threads to cores (`--pin-cores`,
    /// forwarded to every child sweep). Execution-only: never part of
    /// any scenario identity, never perturbs artifact bytes.
    pub pin_cores: bool,
    /// Write the sidecar campaign event log (`events.jsonl`, see
    /// [`crate::obs`]) and forward `--events` to every child sweep.
    /// On by default; `--no-telemetry` disables it. Execution-only:
    /// never part of any scenario identity, never perturbs artifact
    /// bytes.
    pub telemetry: bool,
    /// Host specs the fleet spawns across (`"local"` or
    /// `"ssh:target"`, see [`crate::orchestrator::HostSpec`]).
    /// Empty (the default) = classic single-host launch with no lease
    /// plane. With one or more entries, shards round-robin across the
    /// hosts and every host maintains a renewal lease in the campaign
    /// dir; a host whose lease stops renewing is declared lost and its
    /// shards are reassigned to survivors. Execution-only.
    pub hosts: Vec<String>,
    /// A host whose lease has not renewed for this long is declared
    /// lost (multi-host launches only). Expiry is renewal-driven — the
    /// supervisor watches the lease's counter against its own
    /// monotonic clock, so cross-host wall-clock skew cannot fire it.
    pub lease_timeout_ms: u64,
}

impl LaunchConfig {
    /// Defaults tuned for one multi-core host: auto process count,
    /// single-threaded shards, 30 s stall timeout, 100 ms poll, two
    /// relaunches per failure episode under a 16-relaunch campaign
    /// budget, 100 ms base backoff, quarantine on.
    pub fn new(sweep: SweepConfig) -> Self {
        LaunchConfig {
            sweep,
            procs: 0,
            workers_per_proc: 1,
            stall_timeout_ms: 30_000,
            poll_ms: 100,
            max_retries: 2,
            campaign_retries: 16,
            backoff_ms: 100,
            quarantine: true,
            sampler: RouterSampler::default(),
            rng: RngVersion::default(),
            pin_cores: false,
            telemetry: true,
            hosts: Vec::new(),
            lease_timeout_ms: 10_000,
        }
    }

    /// Effective shard-process count: the explicit `procs`, or
    /// cores / `workers_per_proc` when auto — either way capped to
    /// `cells` (a shard with no trace cells would idle forever).
    pub fn resolve_procs(&self, cells: usize) -> usize {
        let auto = || {
            let cores = std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(1) as u64;
            (cores / self.workers_per_proc.max(1)).max(1)
        };
        let want = if self.procs == 0 { auto() } else { self.procs };
        (want as usize).min(cells.max(1))
    }

    pub fn validate(&self) -> Result<()> {
        self.sweep.validate()?;
        if self.workers_per_proc == 0 {
            return Err(Error::config("workers_per_proc must be positive"));
        }
        if self.stall_timeout_ms == 0 || self.poll_ms == 0 {
            return Err(Error::config(
                "stall_timeout_ms and poll_ms must be positive",
            ));
        }
        if self.stall_timeout_ms < self.poll_ms {
            return Err(Error::config(format!(
                "stall timeout {} ms below poll interval {} ms",
                self.stall_timeout_ms, self.poll_ms
            )));
        }
        if !self.hosts.is_empty() && self.lease_timeout_ms == 0 {
            return Err(Error::config(
                "lease_timeout_ms must be positive for a multi-host launch",
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("sweep", self.sweep.to_json()),
            ("procs", json::num(self.procs as f64)),
            ("workers_per_proc", json::num(self.workers_per_proc as f64)),
            ("stall_timeout_ms", json::num(self.stall_timeout_ms as f64)),
            ("poll_ms", json::num(self.poll_ms as f64)),
            ("max_retries", json::num(self.max_retries as f64)),
            ("campaign_retries", json::num(self.campaign_retries as f64)),
            ("backoff_ms", json::num(self.backoff_ms as f64)),
            ("quarantine", Value::Bool(self.quarantine)),
            ("router", json::s(self.sampler.tag().to_string())),
            ("rng", json::s(self.rng.tag().to_string())),
            ("pin_cores", Value::Bool(self.pin_cores)),
            ("telemetry", Value::Bool(self.telemetry)),
            (
                "hosts",
                json::arr(self.hosts.iter().map(|h| json::s(h.as_str())).collect()),
            ),
            ("lease_timeout_ms", json::num(self.lease_timeout_ms as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        // "router" is the current spelling; pre-flip launch.json files
        // carried `"fast_router": bool` — still accepted, so recorded
        // campaigns keep resuming and auditing under their sampler.
        let sampler = match v.get("router") {
            Some(tag) => RouterSampler::parse(
                tag.as_str()
                    .ok_or_else(|| Error::config("launch router must be a string"))?,
            )?,
            None => RouterSampler::from_fast_flag(
                v.get("fast_router")
                    .and_then(Value::as_bool)
                    .ok_or_else(|| Error::config("launch missing router sampler"))?,
            ),
        };
        let cfg = LaunchConfig {
            sweep: SweepConfig::from_json(
                v.get("sweep").ok_or_else(|| Error::config("launch missing sweep"))?,
            )?,
            procs: v.req_u64("procs")?,
            workers_per_proc: v.req_u64("workers_per_proc")?,
            stall_timeout_ms: v.req_u64("stall_timeout_ms")?,
            poll_ms: v.req_u64("poll_ms")?,
            max_retries: v.req_u64("max_retries")?,
            // absent in pre-fault-plane launch.json files — the
            // defaults reproduce (and bound) the old retry shape
            campaign_retries: v
                .get("campaign_retries")
                .and_then(Value::as_u64)
                .unwrap_or(16),
            backoff_ms: v.get("backoff_ms").and_then(Value::as_u64).unwrap_or(100),
            quarantine: v.get("quarantine").and_then(Value::as_bool).unwrap_or(true),
            sampler,
            // absent in pre-counter-RNG launch.json files — those
            // campaigns were drawn under (and stay on) the v1 streams
            rng: match v.get("rng") {
                Some(tag) => RngVersion::parse(
                    tag.as_str()
                        .ok_or_else(|| Error::config("launch rng must be a string"))?,
                )?,
                None => RngVersion::V1,
            },
            // absent in pre-pinning launch.json files — default off
            pin_cores: v.get("pin_cores").and_then(Value::as_bool).unwrap_or(false),
            // absent in pre-telemetry launch.json files — default on
            // (telemetry is sidecar, so enabling it retroactively
            // cannot change what those campaigns compute)
            telemetry: v.get("telemetry").and_then(Value::as_bool).unwrap_or(true),
            // absent in pre-multi-host launch.json files — empty, the
            // classic single-host launch with no lease plane
            hosts: match v.get("hosts") {
                Some(arr) => arr
                    .as_arr()
                    .ok_or_else(|| Error::config("launch hosts must be an array"))?
                    .iter()
                    .map(|h| {
                        h.as_str().map(str::to_string).ok_or_else(|| {
                            Error::config("launch hosts entries must be strings")
                        })
                    })
                    .collect::<Result<Vec<String>>>()?,
                None => Vec::new(),
            },
            lease_timeout_ms: v
                .get("lease_timeout_ms")
                .and_then(Value::as_u64)
                .unwrap_or(10_000),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Config matching the AOT-exported mini model (python compile.model.E2E)
/// used by the real-execution coordinator.
pub fn tiny() -> ModelConfig {
    ModelConfig {
        layers: 4,
        dense_layers: 1,
        seq: 128,
        hidden: 256,
        heads: 4,
        head_dim: 64,
        kv_heads: 4,
        ffn_dense: 1024,
        ffn_expert: 512,
        n_experts: 8,
        top_k: 2,
        vocab: 8192,
        q_lora_rank: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_model_i_exact() {
        let m = model_i();
        assert_eq!(m.layers, 16);
        assert_eq!(m.seq, 4096);
        assert_eq!(m.hidden, 7168);
        assert_eq!(m.heads, 128);
        assert_eq!(m.ffn_dense, 18432);
        assert_eq!(m.ffn_expert, 2048);
        assert_eq!(m.top_k, 8);
        assert_eq!(m.vocab, 129280);
        assert_eq!(m.q_lora_rank, 1536);
        assert_eq!(m.dense_layers, 3);
        m.validate().unwrap();
    }

    #[test]
    fn table3_model_ii_is_8_layers() {
        let m = model_ii();
        assert_eq!(m.layers, 8);
        assert_eq!(m.hidden, model_i().hidden);
        m.validate().unwrap();
    }

    #[test]
    fn paper_parallel_matches_setup() {
        let p = paper_parallel();
        assert_eq!((p.tp, p.pp, p.ep, p.dp, p.cp, p.vpp), (1, 4, 32, 1, 1, 1));
        assert_eq!(p.micro_batches(), 960);
        assert_eq!(p.world_size(), 128); // 32 EP ranks × 4 PP stages
    }

    #[test]
    fn m_g_formula() {
        let p = paper_parallel();
        // v=1, p=4: m_g = vp + p - 2r - 1 = 7 - 2r
        assert_eq!(p.m_g(0), 7);
        assert_eq!(p.m_g(1), 5);
        assert_eq!(p.m_g(3), 1);
    }

    #[test]
    fn m_g_never_below_one() {
        let mut p = paper_parallel();
        p.pp = 1;
        assert_eq!(p.m_g(0), 1);
    }

    #[test]
    fn layers_per_stage() {
        let p = paper_parallel();
        assert_eq!(p.layers_per_stage(16), 4);
        assert_eq!(p.layers_per_stage(8), 2);
    }

    #[test]
    fn validation_rejects_bad_topk() {
        let mut m = model_i();
        m.top_k = 500;
        assert!(m.validate().is_err());
        m.top_k = 0;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validation_rejects_indivisible_experts() {
        let m = model_i();
        let mut p = paper_parallel();
        p.ep = 33;
        assert!(p.validate(&m).is_err());
    }

    #[test]
    fn validation_rejects_bad_mact_bins() {
        let mut r = paper_run(model_i(), Method::Mact(vec![1, 2, 2]));
        assert!(r.validate().is_err());
        r.method = Method::Mact(vec![]);
        assert!(r.validate().is_err());
        r.method = Method::Mact(vec![1, 2, 4, 8]);
        r.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_model() {
        let m = model_i();
        let v = m.to_json();
        let back = ModelConfig::from_json(&v).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn json_roundtrip_parallel() {
        let p = paper_parallel();
        let parsed =
            ParallelConfig::from_json(&crate::json::parse(&p.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(p, parsed);
    }

    #[test]
    fn method_names_stable() {
        assert_eq!(Method::FullRecompute.name(), "method1/full-recompute");
        assert_eq!(Method::FixedChunk(8).name(), "method2/fixed-c8");
        assert!(Method::Mact(vec![1, 2, 4, 8]).name().contains("1,2,4,8"));
    }

    #[test]
    fn expert_params_scale_with_local_experts() {
        let m = model_i();
        assert_eq!(
            m.expert_params_per_rank(8),
            8 * 3 * 7168 * 2048
        );
    }

    #[test]
    fn method_parse_shorthand() {
        assert_eq!(Method::parse("1").unwrap(), Method::FullRecompute);
        assert_eq!(Method::parse("2").unwrap(), Method::FixedChunk(8));
        assert_eq!(Method::parse("2:4").unwrap(), Method::FixedChunk(4));
        assert_eq!(Method::parse("3").unwrap(), Method::Mact(vec![1, 2, 4, 8]));
        assert_eq!(Method::parse("3:1.4").unwrap(), Method::Mact(vec![1, 4]));
        assert!(Method::parse("9").is_err());
        assert!(Method::parse("2:x").is_err());
        // a likely typo for 2:8 must not silently run full recompute
        assert!(Method::parse("1:8").is_err());
    }

    #[test]
    fn method_json_roundtrip() {
        for m in [
            Method::FullRecompute,
            Method::FixedChunk(4),
            Method::Mact(vec![1, 2, 4, 8]),
        ] {
            let back = Method::from_json(&Method::to_json(&m)).unwrap();
            assert_eq!(m, back);
        }
    }

    #[test]
    fn model_by_name_resolves_presets() {
        assert_eq!(model_by_name("i").unwrap(), model_i());
        assert_eq!(model_by_name("II").unwrap(), model_ii());
        assert!(model_by_name("xxl").is_err());
    }

    #[test]
    fn sweep_config_roundtrip_and_counts() {
        let cfg = SweepConfig::paper_grid(7, 4, 10);
        assert_eq!(cfg.scenario_count(), 2 * 3 * 4);
        cfg.validate().unwrap();
        let back =
            SweepConfig::from_json(&crate::json::parse(&cfg.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn sweep_config_rejects_empty_axes() {
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.seeds.clear();
        assert!(cfg.validate().is_err());
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.iterations = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.models.push("bogus".into());
        assert!(cfg.validate().is_err());
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.methods.push(Method::Mact(vec![]));
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn derived_seeds_deterministic_and_distinct() {
        let a = derive_seeds(7, 8);
        let b = derive_seeds(7, 8);
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 8);
        assert_ne!(derive_seeds(8, 8), a);
        // every derived seed survives the JSON number representation
        assert!(a.iter().all(|&s| s <= MAX_JSON_SEED));
    }

    #[test]
    fn run_config_json_roundtrip() {
        let run = paper_run(model_i(), Method::Mact(vec![1, 2, 4, 8]));
        let text = run.to_json().to_string_compact();
        let back = RunConfig::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(run, back);
        // canonical form is stable call-to-call (hash input stability)
        assert_eq!(text, run.to_json().to_string_compact());
    }

    #[test]
    fn shard_spec_parse_and_ownership() {
        let s = ShardSpec::parse("1/3").unwrap();
        assert_eq!(s, ShardSpec { index: 1, count: 3 });
        assert!(!s.owns(0));
        assert!(s.owns(1));
        assert!(s.owns(4));
        assert!(ShardSpec::parse("3/3").is_err());
        assert!(ShardSpec::parse("0/0").is_err());
        assert!(ShardSpec::parse("02").is_err());
        assert!(ShardSpec::parse("a/b").is_err());
        // every scenario is owned by exactly one shard
        for idx in 0..10usize {
            let owners = (0..3)
                .filter(|&i| ShardSpec { index: i, count: 3 }.owns(idx))
                .count();
            assert_eq!(owners, 1, "scenario {idx}");
        }
    }

    #[test]
    fn sweep_config_rejects_unrepresentable_seed() {
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.seeds.push(u64::MAX);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn launch_config_roundtrip_and_defaults() {
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 4, 10));
        cfg.procs = 3;
        cfg.stall_timeout_ms = 5_000;
        cfg.sampler = RouterSampler::Sequential;
        cfg.rng = RngVersion::V2;
        cfg.pin_cores = true;
        cfg.telemetry = false;
        cfg.hosts = vec!["local".into(), "ssh:worker-2".into()];
        cfg.lease_timeout_ms = 4_000;
        cfg.validate().unwrap();
        let back = LaunchConfig::from_json(
            &crate::json::parse(&cfg.to_json().to_string_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg, back);
        // pre-pinning launch.json files carry no "pin_cores" — absent
        // means off, not a parse error; likewise pre-counter-RNG files
        // carry no "rng" — absent means the v1 streams they recorded
        let mut doc = cfg.to_json();
        if let crate::json::Value::Obj(map) = &mut doc {
            map.remove("pin_cores");
            map.remove("rng");
            // pre-telemetry files carry no "telemetry" — absent means
            // on (sidecar, so retroactively harmless)
            map.remove("telemetry");
            // pre-multi-host files carry neither "hosts" nor
            // "lease_timeout_ms" — absent means the classic
            // single-host launch
            map.remove("hosts");
            map.remove("lease_timeout_ms");
        }
        let legacy = LaunchConfig::from_json(&doc).unwrap();
        assert!(!legacy.pin_cores);
        assert_eq!(legacy.rng, RngVersion::V1);
        assert!(legacy.telemetry);
        assert!(legacy.hosts.is_empty());
        assert_eq!(legacy.lease_timeout_ms, 10_000);
        // defaults are sane and validate; the sampler default is the
        // post-flip splitting multinomial, the RNG default is v1
        let d = LaunchConfig::new(SweepConfig::paper_grid(7, 2, 10));
        d.validate().unwrap();
        assert_eq!(d.procs, 0);
        assert!(d.max_retries >= 1);
        assert_eq!(d.sampler, RouterSampler::Split);
        assert_eq!(d.rng, RngVersion::V1);
    }

    #[test]
    fn launch_config_accepts_legacy_fast_router_field() {
        // pre-flip launch.json files spell the sampler as a bool —
        // they must keep loading under their recorded choice
        let cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 2, 10));
        let mut doc = cfg.to_json();
        if let crate::json::Value::Obj(map) = &mut doc {
            map.remove("router");
            map.insert("fast_router".into(), Value::Bool(false));
        } else {
            panic!("launch config serialises to an object");
        }
        let back = LaunchConfig::from_json(&doc).unwrap();
        assert_eq!(back.sampler, RouterSampler::Sequential);
        let mut doc = cfg.to_json();
        if let crate::json::Value::Obj(map) = &mut doc {
            map.remove("router");
            map.insert("fast_router".into(), Value::Bool(true));
        }
        assert_eq!(
            LaunchConfig::from_json(&doc).unwrap().sampler,
            RouterSampler::Split
        );
        // neither spelling present is an error
        let mut doc = cfg.to_json();
        if let crate::json::Value::Obj(map) = &mut doc {
            map.remove("router");
        }
        assert!(LaunchConfig::from_json(&doc).is_err());
    }

    #[test]
    fn launch_config_resolves_procs_capped_to_cells() {
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 4, 10));
        cfg.procs = 64;
        // the paper grid has 2 models × 4 seeds = 8 trace cells
        assert_eq!(cfg.resolve_procs(8), 8);
        cfg.procs = 2;
        assert_eq!(cfg.resolve_procs(8), 2);
        cfg.procs = 0;
        let auto = cfg.resolve_procs(8);
        assert!((1..=8).contains(&auto));
        // auto divides the cores among each shard's workers
        cfg.workers_per_proc = u64::MAX;
        assert_eq!(cfg.resolve_procs(8), 1);
    }

    #[test]
    fn launch_config_rejects_bad_supervision_params() {
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 2, 10));
        cfg.workers_per_proc = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 2, 10));
        cfg.poll_ms = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 2, 10));
        cfg.stall_timeout_ms = cfg.poll_ms - 1;
        assert!(cfg.validate().is_err());
        // an invalid grid fails launch validation too
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 2, 10));
        cfg.sweep.models.clear();
        assert!(cfg.validate().is_err());
        // a multi-host launch needs a live lease plane
        let mut cfg = LaunchConfig::new(SweepConfig::paper_grid(7, 2, 10));
        cfg.hosts = vec!["local".into()];
        cfg.lease_timeout_ms = 0;
        assert!(cfg.validate().is_err());
        cfg.lease_timeout_ms = 2_000;
        cfg.validate().unwrap();
        // single-host configs don't care about the lease knob
        cfg.hosts.clear();
        cfg.lease_timeout_ms = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn sweep_config_rejects_duplicate_axes() {
        // duplicate method (same resolved name via different spellings)
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.methods.push(Method::FixedChunk(8));
        assert!(cfg.validate().is_err());
        // duplicate model, case-insensitively
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.models.push("I".into());
        assert!(cfg.validate().is_err());
        // duplicate model through an alias spelling ("1" resolves to "i")
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.models.push("1".into());
        assert!(cfg.validate().is_err());
        // duplicate seed
        let mut cfg = SweepConfig::paper_grid(7, 2, 10);
        cfg.seeds.push(cfg.seeds[0]);
        assert!(cfg.validate().is_err());
    }
}
