//! `cargo bench --bench sweep_scaling` — wall-clock scaling of the
//! parallel scenario-sweep engine vs the serial baseline, on the
//! paper's 24-scenario comparison grid (2 models × 3 methods × 4
//! seeds). Also re-asserts the determinism contract: every worker
//! count must emit the serial run's exact JSON bytes.

use std::time::Instant;

use memfine::bench::{fmt_time, BenchReport};
use memfine::config::SweepConfig;
use memfine::sweep;

fn main() {
    memfine::logging::init();
    let cfg = SweepConfig::paper_grid(7, 4, 10);
    println!(
        "grid: {} scenarios ({} iterations each), host parallelism {}",
        cfg.scenario_count(),
        cfg.iterations,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    // Warm-up (first run pays allocator/page-cache costs).
    sweep::run_sweep(&cfg, 1).expect("warmup sweep");

    let t0 = Instant::now();
    let serial = sweep::run_sweep(&cfg, 1).expect("serial sweep");
    let serial_s = t0.elapsed().as_secs_f64();
    let serial_json = serial.to_json().to_string_pretty();

    let mut report = BenchReport::new(
        "sweep scaling — serial vs worker pool",
        &["workers", "wall clock", "speedup", "bit-identical"],
    );
    report.row(&[
        "1".into(),
        fmt_time(serial_s),
        "1.00x".into(),
        "yes (baseline)".into(),
    ]);
    for workers in [2usize, 4, 8] {
        let t0 = Instant::now();
        let out = sweep::run_sweep(&cfg, workers).expect("parallel sweep");
        let wall = t0.elapsed().as_secs_f64();
        let identical = out.to_json().to_string_pretty() == serial_json;
        assert!(identical, "workers={workers} diverged from serial output");
        report.row(&[
            workers.to_string(),
            fmt_time(wall),
            format!("{:.2}x", serial_s / wall),
            "yes".into(),
        ]);
    }
    report.print();
    println!("\nreading: scenarios are independent pure functions, so the pool");
    println!("scales with cores until the grid runs out of work; output bytes");
    println!("never depend on the schedule.");
}
